"""Paper Fig. 3 — token-generation throughput vs available memory, for
several partial-quantization levels.

Two modes:

1. ANALYTIC, full scale (the paper's own numbers). The cost model
   (core/cost_model.py) with the paper's A100+PCIe constants and the real
   Mixtral-8x7B sizes from our config. The paper reports 0.63 -> 13.00
   tok/s across budgets 26.28 -> 53.03 GB under maximum quantization; the
   paper's measured per-expert transfer (336 MB in 27.35 ms => 12.3 GB/s
   effective PCIe) pins the offload term. Claims:
     F1  hyperbolic throughput growth in the offloading region;
     F2  all-resident plateau once the budget fits the model;
     F3  in the plateau, MORE quantization LOWERS throughput on the
         paper's stack (bnb 4-bit matmul slower than 16-bit) — our Pallas
         fused dequant-matmul inverts this (beyond-paper; §Perf).

2. MEASURED, reduced scale: the continuous-batching AdaptiveServingEngine
   on the trained bench MoE, on this container's CPU — Poisson request
   arrivals joining/leaving decode slots mid-batch, real tokens,
   wall-clock decode, expert streaming MEASURED through the runtime
   ExpertCache (the analytical estimate is reported alongside as a
   cross-check). Reports tokens/s AND p50/p95 per-request latency —
   the QoS pair the paper's knobs trade against each other.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from benchmarks import common
from repro.configs import get_config
from repro.core.cost_model import HardwareModel, estimate_qos
from repro.core.planner import AdaptivePlanner

# The paper's testbed: A100-80GB (HBM2e ~2 TB/s) + PCIe gen4. Two measured
# constants pin the model: (a) 336 MB expert / 27.35 ms = 12.3 GB/s
# effective host->GPU link; (b) the all-resident bf16 plateau of
# ~13 tok/s => 24.7 GB of active weights / (2 TB/s * MBU) = 77 ms/token
# => MBU ~= 0.17 (single-stream HuggingFace/PyTorch serving overhead).
# The transfer term has NO free parameter; the compute term has this one.
PAPER_HW = HardwareModel(
    peak_flops=312e12, hbm_bw=2.0e12, host_link_bw=12.3e9,
    hbm_bytes=80e9, mbu=0.17,
    q4_speedup_decode=0.85,     # paper: bnb 4-bit matmul SLOWER than 16-bit
    q4_speedup_prefill=0.85,
)
# Same machine, our fused dequant-matmul instead of bnb (beyond-paper).
OURS_HW = HardwareModel(
    peak_flops=312e12, hbm_bw=2.0e12, host_link_bw=12.3e9,
    hbm_bytes=80e9, mbu=0.17,
    q4_speedup_decode=2.8, q4_speedup_prefill=0.95,
)


def analytic_surface(hw: HardwareModel, tag: str) -> List[Dict]:
    cfg = get_config("mixtral-8x7b")
    planner = AdaptivePlanner(cfg, hw=hw)
    total = planner.num_experts_total
    rows = []
    for mem_gb in (24, 26.28, 30, 34, 38, 42, 46, 50, 53.03, 60, 100):
        for frac in (0.0, 0.5, 1.0):
            nq = int(round(frac * total))
            res = planner.plan(mem_gb * 1e9, "quality", nq, batch_size=1)
            rows.append({
                "bench": f"fig3_analytic_{tag}", "mem_gb": mem_gb,
                "frac_q": frac,
                "tok_s": round(res.qos.tokens_per_s, 3),
                "hit_rate": round(res.qos.hit_rate, 3),
                "resident": round(res.plan.resident_fraction(), 3),
                "t_compute_ms": round(res.qos.t_compute_ms, 2),
                "t_transfer_ms": round(res.qos.t_transfer_ms, 2),
            })
    return rows


def measured_small_scale(quick: bool = False) -> List[Dict]:
    """Declarative-surface measured mode (DESIGN.md §9): each operating
    point is a QoSTarget and the engine picks the frontier point."""
    import math
    from repro.serving.api import EngineConfig, QoSTarget, build_engine
    from repro.serving.driver import drive_poisson
    from repro.serving.qos import QoSController
    cfg, params, _ = common.get_trained_model()
    rng = np.random.default_rng(0)
    rows = []
    engine = build_engine(cfg, params,
                          EngineConfig(max_slots=4, max_len=96))
    controller = QoSController(engine)
    size16 = common.model_size_bytes(cfg, 0)
    size4 = common.model_size_bytes(cfg, cfg.num_layers
                                    * cfg.moe.num_experts)
    ne = cfg.non_expert_bytes()
    # budgets relative to the EXPERT bytes (non-expert floor always
    # fits); max_quality_loss=0 pins the bf16 point, inf tokens/s chases
    # the fastest (all-4-bit) point under the budget
    targets = [
        ("all_resident_fp16",
         QoSTarget(mem_budget_bytes=size16 * 1.05, max_quality_loss=0.0,
                   min_tokens_per_s=math.inf)),
        ("all_resident_q4",
         QoSTarget(mem_budget_bytes=size4 * 1.3,
                   min_tokens_per_s=math.inf)),
        ("offload_half",
         QoSTarget(mem_budget_bytes=ne + (size4 - ne) * 0.5,
                   min_tokens_per_s=math.inf)),
    ]
    for name, target in targets:
        point = controller.set_target(target)
        rids = drive_poisson(engine, rng,
                             n_requests=4 if quick else 8,
                             mean_gap_s=0.05,
                             on_iteration=controller.step)
        lats = [engine.done[r].latency_s for r in rids]
        plan = engine.planner.current.plan
        rows.append({
            "bench": "fig3_measured", "point": name,
            "slo": target.describe(),
            "selected": point.summary(),
            "budget_mb": round(target.mem_budget_bytes / 1e6, 2),
            "frac_q": round(plan.num_q_experts / plan.quant.size, 3),
            "miss_rate_est": round(engine.metrics["miss_rate"], 3),
            "miss_rate_measured": round(
                engine.metrics["miss_rate_measured"], 3),
            "transfer_s_measured": round(engine.metrics["transfer_s"], 4),
            "transfer_s_est": round(engine.metrics["transfer_s_est"], 4),
            "tok_s_compute_only": round(
                engine.throughput_tokens_per_s(include_transfer=False), 2),
            "tok_s_with_transfer": round(
                engine.throughput_tokens_per_s(include_transfer=True), 2),
            "latency_p50_ms": round(float(np.percentile(lats, 50)) * 1e3, 1),
            "latency_p95_ms": round(float(np.percentile(lats, 95)) * 1e3, 1),
        })
        # reset throughput counters between operating points
        engine.reset_counters()
    return rows


def multi_tenant_surface(quick: bool = False) -> List[Dict]:
    """Beyond-paper multi-tenant mode (DESIGN.md §10): two MoE tenants —
    an interactive tenant with a tokens/s floor and a quality-pinned
    batch tenant — share ONE A100-sized budget through the water-filling
    ResourceArbiter, on the deterministic simulator over the PAPER_HW
    frontier. Reports the per-tenant operating points across global
    budgets plus the partial-migration cost of a budget shrink."""
    from repro.core.pareto import ParetoFrontier, QoSTarget
    from repro.serving.multi import MultiTenantEngine, TenantSpec
    from repro.serving.qos import QoSControllerConfig
    from repro.serving.simulator import SimulatedEngine, VirtualClock

    cfg = get_config("mixtral-8x7b")
    frontier = ParetoFrontier(cfg, PAPER_HW)
    peak = max(p.qos.tokens_per_s for p in frontier.points)
    specs = [
        ("interactive", QoSTarget(min_tokens_per_s=round(0.5 * peak, 2)),
         2.0),
        ("batch", QoSTarget(max_quality_loss=0.0, min_tokens_per_s=0.5),
         1.0),
    ]
    rows: List[Dict] = []
    budgets = (40, 60) if quick else (40, 60, 80)
    for budget_gb in budgets:
        clock = VirtualClock()
        mt = MultiTenantEngine(
            budget_gb * 1e9,
            controller_config=QoSControllerConfig(
                min_dwell_iterations=4, window_iterations=2))
        engines = {}
        for name, target, weight in specs:
            engines[name] = SimulatedEngine(model_error=1.0, clock=clock)
            mt.add_tenant(TenantSpec(name, target, weight=weight),
                          engines[name], frontier)
        mt.arbitrate()
        for _ in range(40):
            for eng in engines.values():
                eng.run_iteration()
            mt.step()
        for name, t in mt.tenants.items():
            p = t.point
            rows.append({
                "bench": "fig3_multi_tenant", "budget_gb": budget_gb,
                "tenant": name, "slo": t.spec.target.describe(),
                "alloc_gb": round(t.allocated_bytes / 1e9, 2),
                "frac_q": round(p.num_q_experts / p.plan.quant.size, 3),
                "resident": round(p.plan.resident_fraction(), 3),
                "tok_s_analytic": round(p.qos.tokens_per_s, 3),
                "tok_s_measured": round(
                    t.controller.metrics["last_measured_tps"], 3),
                "ppl_x": round(p.qos.quality_proxy, 4),
            })
        # the job manager reclaims 25% of the envelope: one joint
        # re-arbitration, partial migrations only. Report the SHRINK's
        # own cost (delta over pre-shrink counters), not lifetime totals.
        before = dict(mt.metrics)
        reports0 = len(mt.reports)
        mt.set_budget(0.75 * budget_gb * 1e9)
        shrink_replans = int(mt.metrics["replans"] - before["replans"])
        rows.append({
            "bench": "fig3_multi_tenant_shrink", "budget_gb": budget_gb,
            "shrunk_to_gb": round(0.75 * budget_gb, 1),
            "arbitrations": int(mt.metrics["arbitrations"]
                                - before["arbitrations"]),
            "replans": shrink_replans,
            "migrated_experts": sum(r.migrated_experts
                                    for r in mt.reports[reports0:]),
            "migrated_experts_full_reload_equiv":
                shrink_replans * cfg.num_layers * cfg.moe.num_experts,
            "migrated_gib": round(
                (mt.metrics["migrated_bytes"] - before["migrated_bytes"])
                / 2**30, 3),
            "downtime_ms_est": round(
                (mt.metrics["downtime_s"] - before["downtime_s"]) * 1e3, 2),
        })
    return rows


def overlap_ab(quick: bool = False) -> List[Dict]:
    """Async-vs-sync expert streaming A/B (DESIGN.md §12) on the
    deterministic simulator: the same transfer-bound frontier point runs
    identical scripted compute/transfer timings with overlap off (the
    paper's serial staging) and on (the async pipeline, which exposes
    only ``max(0, transfer - compute)``). Writes the per-iteration
    throughput trajectory to ``results/bench_overlap.json``."""
    import json

    from repro.core.pareto import ParetoFrontier
    from repro.serving.simulator import SimulatedEngine

    cfg = get_config("mixtral-8x7b")
    frontier = ParetoFrontier(cfg, PAPER_HW)
    # the paper's offloading region, at the point with the largest
    # hideable fraction min(t_transfer, t_compute) / t_token — where the
    # pipeline's win is biggest (up to 2x when the two balance)
    point = max((p for p in frontier.points if p.qos.t_transfer_ms > 0),
                key=lambda p: min(p.qos.t_transfer_ms, p.qos.t_compute_ms)
                / (p.qos.t_transfer_ms + p.qos.t_compute_ms))
    iters = 16 if quick else 64
    rows: List[Dict] = []
    traj: Dict[str, Dict] = {
        "bench": "overlap_ab", "point": point.summary(),
        "iterations": iters,
    }
    for mode in ("sync", "async"):
        eng = SimulatedEngine(
            batch=1,
            throughput_fn=lambda p, i: 1e3 / p.qos.t_compute_ms,
            transfer_fn=lambda p, i: p.qos.t_transfer_ms / 1e3,
            overlap=(mode == "async"), overlap_efficiency=1.0)
        eng.apply_frontier_point(point)
        tok_s_t = []
        for _ in range(iters):
            eng.run_iteration()
            m = eng.metrics
            tok_s_t.append(round(
                m["tokens_generated"]
                / (m["decode_s"] + m["transfer_exposed_s"]), 4))
        m = eng.metrics
        rows.append({
            "bench": "fig3_overlap_ab", "mode": mode,
            "point": point.summary(),
            "tok_s": tok_s_t[-1],
            "transfer_s": round(m["transfer_s"], 4),
            "transfer_exposed_s": round(m["transfer_exposed_s"], 4),
            "transfer_hidden_s": round(
                m["transfer_s"] - m["transfer_exposed_s"], 4),
            "wall_s": round(eng.clock.now(), 4),
        })
        traj[mode] = {"tok_s_per_iteration": tok_s_t,
                      "transfer_s": rows[-1]["transfer_s"],
                      "transfer_exposed_s": rows[-1]["transfer_exposed_s"],
                      "wall_s": rows[-1]["wall_s"]}
    traj["async_speedup"] = round(rows[1]["tok_s"] / rows[0]["tok_s"], 4)
    assert rows[1]["tok_s"] > rows[0]["tok_s"], \
        "async must beat sync on a transfer-bound config"
    assert rows[1]["transfer_exposed_s"] < rows[1]["transfer_s"]
    out = common.RESULTS / "bench_overlap.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(traj, indent=2) + "\n")
    rows.append({"bench": "fig3_overlap_claims",
                 "async_speedup": traj["async_speedup"],
                 "trajectory": str(out)})
    return rows


def dynamic_ab(quick: bool = False) -> List[Dict]:
    """Static-vs-dynamic precision A/B (DESIGN.md §15) on the
    deterministic simulator: a mixed-rung fully-resident frontier point
    serves Zipf-skewed traffic; the DynamicPrecisionController folds the
    measured routing histogram into the sensitivity profile and issues
    byte-neutral rung swaps. The acceptance claim: the dynamic plan
    reaches STRICTLY lower traffic-weighted quality cost than the static
    balanced plan at the exact same device byte budget. Writes
    ``results/bench_dynamic.json``."""
    import json

    from repro.configs import reduce_for_smoke
    from repro.core.cost_model import device_bytes
    from repro.core.dynamic_precision import DynamicPrecisionController
    from repro.core.pareto import ParetoFrontier
    from repro.core.sensitivity import SensitivityProfile
    from repro.serving.simulator import SimulatedEngine, zipf_route_fn

    # the reduced config: with few layers a single hot/cold rung swap is
    # a meaningful fraction of the plan's quality cost, so the
    # hysteresis margin plays at realistic scale (tests use the same)
    cfg = reduce_for_smoke(get_config("mixtral-8x7b"))
    frontier = ParetoFrontier(cfg, HardwareModel())
    # mixed-rung + full residency: swaps are pure quality moves
    pts = [p for p in frontier.all_points
           if 0 < p.num_q_experts < p.plan.bits.size
           and p.plan.resident_fraction() == 1.0]
    point = pts[len(pts) // 2]
    L, E = point.plan.bits.shape
    iters = 16 if quick else 40
    eng = SimulatedEngine(batch=4, route_fn=zipf_route_fn(L, E, seed=3))
    eng.apply_frontier_point(point)
    ctl = DynamicPrecisionController(eng, SensitivityProfile.uniform(cfg))
    for _ in range(iters):
        eng.run_iteration()
        ctl.step()
    static, final = point.plan, eng.current_plan
    # quality under the SAME traffic-folded profile the controller
    # descends — the measured objective, not the flat prior
    q_static = ctl.profile.quality_cost(static)
    q_dynamic = ctl.profile.quality_cost(final)
    bytes_static = int(device_bytes(cfg, static))
    bytes_dynamic = int(device_bytes(cfg, final))
    assert q_dynamic < q_static, \
        "dynamic precision must strictly beat the static balanced plan"
    assert bytes_dynamic == bytes_static, "rung swaps must be byte-neutral"
    hot, cold = final.bits[:, :E // 2], final.bits[:, E // 2:]
    doc = {
        "bench": "fig3_dynamic_ab", "point": point.summary(),
        "iterations": iters,
        "quality_cost_static": round(q_static, 6),
        "quality_cost_dynamic": round(q_dynamic, 6),
        "quality_cost_reduction": round(1.0 - q_dynamic / q_static, 4),
        "device_bytes": bytes_static,
        "swaps": int(ctl.metrics["swaps"]),
        "rung_promotions": int(ctl.metrics["rung_promotions"]),
        "rung_demotions": int(ctl.metrics["rung_demotions"]),
        "hot_rung_mean": round(float(hot.mean()), 3),
        "cold_rung_mean": round(float(cold.mean()), 3),
    }
    out = common.RESULTS / "bench_dynamic.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(doc, indent=2) + "\n")
    return [doc, {"bench": "fig3_dynamic_ab_claims",
                  "dynamic_strictly_better": True,
                  "byte_neutral": True, "results": str(out)}]


def ep_ab(quick: bool = False) -> List[Dict]:
    """EP=1 vs EP=4 analytic decode A/B (DESIGN.md §16) at kimi scale
    (61 layers x 384 experts, ~1T params — the regime EP exists for).
    Both sides get the SAME per-device HBM budget; the EP=4 planner's
    budget buys LOCAL residency on each of the 4 shards, so the
    aggregate accelerator-resident set is up to 4x larger and the
    surplus rides the PEER tier (NVLink-class streaming + all2all
    latency) instead of the host link. The acceptance claim: at an
    H200-class budget EP=4 strictly beats EP=1 decode throughput by a
    healthy margin, and never loses at any budget. Writes
    ``results/bench_ep.json``."""
    import json

    cfg = get_config("kimi-k2-1t-a32b")
    hw = HardwareModel()
    budgets_gb = (141,) if quick else (40, 80, 141)
    rows: List[Dict] = []
    by_budget: Dict[float, Dict[int, float]] = {}
    for budget_gb in budgets_gb:
        by_budget[budget_gb] = {}
        for ep in (1, 4):
            planner = AdaptivePlanner(cfg, hw=hw, ep=ep)
            res = planner.plan(budget_gb * 1e9, "throughput",
                               batch_size=1)
            q, place = res.qos, res.plan.placement_counts()
            by_budget[budget_gb][ep] = q.tokens_per_s
            rows.append({
                "bench": "fig3_ep_ab", "mem_gb": budget_gb, "ep": ep,
                "tok_s": round(q.tokens_per_s, 3),
                "hit_rate": round(q.hit_rate, 4),
                "device_experts": place["device"],
                "peer_experts": place["peer"],
                "host_experts": place["host"],
                "t_compute_ms": round(q.t_compute_ms, 3),
                "t_peer_ms": round(q.t_peer_ms, 3),
                "t_exposed_ms": round(q.t_exposed_ms, 3),
            })
    speedups = {gb: round(v[4] / v[1], 3) for gb, v in by_budget.items()}
    headline = speedups[141]
    # EP must never LOSE (the peer tier strictly dominates the host link
    # it displaces), and at H200 scale the 4x aggregate residency is
    # worth >= 2x decode throughput (observed ~3.5x; conservative gate)
    assert all(s >= 1.0 for s in speedups.values()), speedups
    assert headline >= 2.0, \
        f"EP=4 speedup {headline} < 2.0 at the 141 GB budget"
    doc = {
        "bench": "fig3_ep_ab", "arch": cfg.arch_id,
        "per_device_budgets_gb": list(budgets_gb),
        "rows": rows,
        "speedup_ep4_over_ep1": speedups,
        "headline_speedup_141gb": headline,
    }
    out = common.RESULTS / "bench_ep.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(doc, indent=2) + "\n")
    rows.append({"bench": "fig3_ep_ab_claims",
                 "ep4_never_loses": True,
                 "headline_speedup_141gb": headline,
                 "results": str(out)})
    return rows


def spec_ab(quick: bool = False) -> List[Dict]:
    """Plain vs ladder-draft self-speculative decode A/B (DESIGN.md §17).

    MEASURED half, reduced scale: the real AdaptiveServingEngine decodes
    the SAME greedy request set with ``speculate=0`` and ``speculate=K``
    on the trained bench MoE; asserts exact token identity and measures
    the acceptance rate + wall-clock tokens/s. On this container's CPU
    the draft forward costs nearly as much as the verify (jitted XLA
    matmuls at toy sizes are compute-bound, not weight-bandwidth-bound),
    so the MEASURED wall-clock ratio is reported transparently but NOT
    gated — the asymmetry that makes drafting cheap (int4 banks read
    16/4x fewer HBM bytes) is an accelerator memory-bandwidth property
    the analytic model prices.

    ANALYTIC half, full scale: the cost model prices the same
    draft/verify cycle on mixtral-8x7b and the kimi-scale config at the
    MEASURED acceptance rate — serve all-16-bit fully resident, draft
    every expert at the int4 rung through the fused kernel. The CI gate
    holds the headline analytic speedup >= 1.5x. Writes
    ``results/bench_spec.json``."""
    import dataclasses
    import json

    from repro.serving.api import (EngineConfig, QoSTarget, ServeRequest,
                                   build_engine)

    k = 3
    cfg, params, _ = common.get_trained_model()
    rng = np.random.default_rng(0)
    n_req = 3 if quick else 6
    max_new = 16 if quick else 24
    prompts = [rng.integers(1, cfg.vocab_size, 8) for _ in range(n_req)]
    runs: Dict[str, Dict] = {}
    for mode, depth in (("plain", 0), ("spec", k)):
        engine = build_engine(cfg, params, EngineConfig(
            max_slots=2, max_len=8 + max_new, speculate=depth))
        # serve at the all-resident bf16 quality point, so the int4
        # draft is a genuinely different (cheaper) model
        engine.apply_target(QoSTarget(
            mem_budget_bytes=common.model_size_bytes(cfg, 0) * 1.05,
            max_quality_loss=0.0))
        for p in prompts:
            engine.submit_request(ServeRequest(prompt=p,
                                               max_new_tokens=max_new))
        while engine.has_work():
            engine.run_iteration(temperature=0.0)
        m = engine.metrics
        runs[mode] = {
            "tokens": [list(engine.result(rid).tokens)
                       for rid in sorted(engine.done)],
            "iterations": int(m["iterations"]),
            "tok_s_measured_wall": round(
                m["tokens_generated"] / max(m["decode_s"], 1e-9), 3),
            "spec_proposed": int(m["spec_proposed"]),
            "spec_accepted": int(m["spec_accepted"]),
            "acceptance_rate": round(float(m["acceptance_rate"]), 4),
        }
        engine.close()
    assert runs["plain"]["tokens"] == runs["spec"]["tokens"], \
        "greedy speculative decode must be token-identical to plain"
    acc = runs["spec"]["acceptance_rate"]
    assert runs["spec"]["iterations"] < runs["plain"]["iterations"], \
        "accepted drafts must reduce decode iterations"

    from repro.core.cost_model import draft_token_time
    analytic: Dict[str, Dict] = {}
    for arch in ("mixtral-8x7b", "kimi-k2-1t-a32b"):
        acfg = get_config(arch)
        hw = HardwareModel()
        planner = AdaptivePlanner(acfg, hw=hw)
        # the all-resident bf16 plateau (the paper's quality-first serve
        # point): every expert 16-bit on device, so the int4 draft rung
        # reads ~4x fewer bytes and the cycle asymmetry is largest
        full = acfg.non_expert_bytes() + acfg.num_layers \
            * acfg.moe.num_experts * acfg.expert_param_bytes(16)
        res = planner.plan(full * 1.05, "quality", 0, batch_size=1)
        plain_qos = estimate_qos(acfg, res.plan, hw)
        spec_qos = estimate_qos(
            acfg, res.plan,
            dataclasses.replace(hw, spec_k=k, spec_acceptance=acc))
        analytic[arch] = {
            "tok_s_plain": round(plain_qos.tokens_per_s, 3),
            "tok_s_spec": round(spec_qos.tokens_per_s, 3),
            "t_token_ms": round(plain_qos.t_compute_ms
                                + plain_qos.t_exposed_ms, 2),
            "t_draft_ms": round(
                draft_token_time(acfg, res.plan, hw) * 1e3, 2),
            "tokens_per_cycle": round(spec_qos.spec_tokens_per_cycle, 3),
            "tok_s_speedup_analytic": round(
                spec_qos.tokens_per_s / plain_qos.tokens_per_s, 3),
        }
    headline = max(a["tok_s_speedup_analytic"] for a in analytic.values())
    assert all(a["tok_s_speedup_analytic"] > 1.0
               for a in analytic.values()), analytic
    assert headline >= 1.5, \
        f"analytic speculative speedup {headline} < 1.5x at measured " \
        f"acceptance {acc}"
    doc = {
        "bench": "fig3_spec_ab", "k": k,
        "greedy_token_identical": True,
        "measured": {
            "arch": cfg.arch_id,
            "acceptance_rate": acc,
            "plain": {kk: v for kk, v in runs["plain"].items()
                      if kk != "tokens"},
            "spec": {kk: v for kk, v in runs["spec"].items()
                     if kk != "tokens"},
            "tok_s_speedup_measured_wall": round(
                runs["spec"]["tok_s_measured_wall"]
                / max(runs["plain"]["tok_s_measured_wall"], 1e-9), 3),
            "iteration_reduction": round(
                1.0 - runs["spec"]["iterations"]
                / runs["plain"]["iterations"], 3),
        },
        "analytic_at_measured_acceptance": analytic,
        "headline_speedup_analytic": headline,
        "speedup_gate": 1.5,
    }
    out = common.RESULTS / "bench_spec.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(doc, indent=2) + "\n")
    return [doc, {"bench": "fig3_spec_ab_claims",
                  "greedy_token_identical": True,
                  "acceptance_rate": acc,
                  "headline_speedup_analytic": headline,
                  "results": str(out)}]


def run(quick: bool = False) -> List[Dict]:
    rows = analytic_surface(PAPER_HW, "paper_stack")
    rows += analytic_surface(OURS_HW, "fused_kernel")
    rows += multi_tenant_surface(quick)
    rows += overlap_ab(quick)
    rows += dynamic_ab(quick)
    rows += ep_ab(quick)
    rows += measured_small_scale(quick)
    rows += spec_ab(quick)

    # -- claim checks ------------------------------------------------------
    # The paper's 0.63 -> 13.00 tok/s range spans its WHOLE config space:
    # 0.63 = 26.28 GB with 16-bit experts (hit rate ~27%, offload-bound);
    # 13.0 = everything resident.
    paper = [r for r in rows if r["bench"] == "fig3_analytic_paper_stack"]
    grid = [r for r in paper if 26.28 <= r["mem_gb"] <= 53.03]
    lo = min(grid, key=lambda r: r["tok_s"])
    hi = max(grid, key=lambda r: r["tok_s"])
    # F1: hyperbolic growth — tok/s span far exceeds the budget span
    f1 = hi["tok_s"] / max(lo["tok_s"], 1e-9)
    budget_ratio = (53.03 - 3.16) / (26.28 - 3.16)
    # F2/F3 at an all-resident point for BOTH precisions (>= 95 GB)
    plateau = [r for r in paper if r["mem_gb"] >= 95]
    f3_paper = (next(r for r in plateau if r["frac_q"] == 1.0)["tok_s"]
                < next(r for r in plateau if r["frac_q"] == 0.0)["tok_s"])
    ours_plateau = [r for r in rows
                    if r["bench"] == "fig3_analytic_fused_kernel"
                    and r["mem_gb"] >= 95]
    f3_ours = (next(r for r in ours_plateau if r["frac_q"] == 1.0)["tok_s"]
               > next(r for r in ours_plateau if r["frac_q"] == 0.0)["tok_s"])
    claims = {
        "bench": "fig3_claims",
        "paper_range_tok_s": [0.63, 13.00],
        "ours_range_tok_s": [lo["tok_s"], hi["tok_s"]],
        "range_endpoints_within_2x": bool(
            0.5 < lo["tok_s"] / 0.63 < 2.0 and 0.5 < hi["tok_s"] / 13.0 < 2.0),
        "F1_growth_ratio": round(f1, 2),
        "F1_pass": bool(f1 > 2 * budget_ratio),
        "F2_plateau_tok_s": plateau[0]["tok_s"],
        "F3_paper_stack_quant_slower": bool(f3_paper),
        "F3_fused_kernel_quant_faster": bool(f3_ours),
    }
    rows.append(claims)
    common.write_rows("fig3_throughput", rows)
    return rows


def main():
    import argparse
    ap = argparse.ArgumentParser(
        description="Fig. 3 throughput benchmarks")
    ap.add_argument("--quick", action="store_true",
                    help="reduced iteration counts for CI smoke")
    ap.add_argument("--dynamic-ab", action="store_true",
                    help="run ONLY the static-vs-dynamic precision A/B "
                         "(writes results/bench_dynamic.json)")
    ap.add_argument("--ep-ab", action="store_true",
                    help="run ONLY the EP=1 vs EP=4 analytic decode A/B "
                         "at kimi scale (writes results/bench_ep.json)")
    ap.add_argument("--spec-ab", action="store_true",
                    help="run ONLY the plain vs speculative decode A/B "
                         "(DESIGN.md §17): measured greedy identity + "
                         "acceptance on the bench MoE, analytic speedup "
                         "at mixtral/kimi scale (writes "
                         "results/bench_spec.json)")
    args = ap.parse_args()
    if args.dynamic_ab:
        rows = dynamic_ab(args.quick)
    elif args.ep_ab:
        rows = ep_ab(args.quick)
    elif args.spec_ab:
        rows = spec_ab(args.quick)
    else:
        rows = run(args.quick)
    for r in rows:
        print(r)


if __name__ == "__main__":
    main()
