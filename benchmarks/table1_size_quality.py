"""Paper Table 1 — model size & perplexity: homogeneous quantization vs
expert-only partial quantization.

Rows (reduced-scale protocol on the trained bench MoE):
  16/16      — bf16 everything (reference quality, largest);
  8/8        — homogeneous 8-bit (all matrices incl. non-expert);
  4/4        — homogeneous 4-bit (the paper's worst-quality row);
  16/mix     — non-expert 16-bit + {0%, 50%, 100%} experts 4-bit
               (the paper's contribution: a SIZE RANGE at near-16-bit ppl).

Also reports the FULL-SCALE Mixtral-8x7B analytic sizes from the exact
config shapes next to the paper's GB numbers (Table 1 column 3).

Claims validated:
  T1  partial(100%) ppl  <<  homogeneous-4/4 ppl  (experts are the cheap
      95% of bytes; non-expert layers are the quality-critical 5%);
  T2  partial size range spans below the 8/8 point while keeping ppl
      within a few percent of 16/16.
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks import common
from repro.configs import get_config
from repro.core.precision_plan import balanced_random_plan


def full_scale_sizes() -> Dict[str, float]:
    """Analytic Mixtral-8x7B sizes (GB) vs the paper's Table 1."""
    cfg = get_config("mixtral-8x7b")
    total = cfg.num_layers * cfg.moe.num_experts
    gb = 1e9
    return {
        "16/16_gb": round(common.model_size_bytes(cfg, 0) / gb, 2),
        "16/mix_min_gb": round(common.model_size_bytes(cfg, total) / gb, 2),
        "4/4_gb": round(common.model_size_bytes(cfg, total,
                                                non_expert_bits=4) / gb, 2),
        "8/8_gb": round(common.model_size_bytes(
            cfg.replace(mop=cfg.mop.__class__(enabled=True, bits=8,
                                              group_size=64)),
            total, non_expert_bits=8) / gb, 2),
        "paper_16/16_gb": 94.21, "paper_4/4_gb": 23.55,
        "paper_8/8_gb": 47.10, "paper_mix_range_gb": [26.62, 94.21],
    }


def run(quick: bool = False) -> List[Dict]:
    cfg, params, eval_batches = common.get_trained_model()
    total = cfg.num_layers * cfg.moe.num_experts
    g = cfg.mop.group_size
    rows: List[Dict] = []

    def add(name, p, size_bytes):
        ppl = common.eval_perplexity(cfg, p, eval_batches)
        rows.append({"bench": "table1", "config": name,
                     "size_bytes": int(size_bytes),
                     "size_rel": round(size_bytes
                                       / common.model_size_bytes(cfg, 0), 3),
                     "ppl": round(ppl, 4)})
        return ppl

    ppl16 = add("16/16", params, common.model_size_bytes(cfg, 0))
    add("8/8", common.fake_quant_tree(params, 8, g),
        common.model_size_bytes(
            cfg.replace(mop=cfg.mop.__class__(enabled=True, bits=8,
                                              group_size=g)),
            total, non_expert_bits=8))
    ppl44 = add("4/4", common.fake_quant_tree(params, 4, g),
                common.model_size_bytes(cfg, total, non_expert_bits=4))
    mix_ppls = []
    for frac in (0.5, 1.0):
        nq = int(round(frac * total))
        plan = balanced_random_plan(cfg.num_layers, cfg.moe.num_experts, nq,
                                    bits=4, group_size=g, seed=0)
        p = common.fake_quant_experts(params, cfg, plan)
        mix_ppls.append(add(f"16/mix({frac:.0%})", p,
                            common.model_size_bytes(cfg, nq)))

    worst_mix = max(mix_ppls)
    claims = {
        "bench": "table1_claims",
        "T1_partial_vs_homog4": round(ppl44 - worst_mix, 4),
        "T1_pass": bool(worst_mix < ppl44),
        "T2_mix_ppl_overhead": round(worst_mix / ppl16 - 1.0, 4),
        "T2_pass": bool(worst_mix / ppl16 < 1.2),
        "full_scale_sizes": full_scale_sizes(),
    }
    rows.append(claims)
    common.write_rows("table1_size_quality", rows)
    return rows


def main():
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
