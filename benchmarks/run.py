"""Benchmark driver: one module per paper table/figure + the kernel bench.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig2,...]

Writes results/bench/<name>.json and prints one CSV line per headline
number: ``name,us_per_call,derived``.
"""
from __future__ import annotations

import argparse
import time

from benchmarks import cache_sim, fig2_quality, fig3_throughput, \
    kernel_bench, table1_size_quality

BENCHES = {
    "fig2": fig2_quality.run,
    "fig3": fig3_throughput.run,
    "table1": table1_size_quality.run,
    "kernel": kernel_bench.run,
    "cache": cache_sim.run,
}


def _headline(name: str, rows) -> list:
    """(name, us_per_call, derived) summary lines per bench."""
    out = []
    if name == "fig2":
        c = next(r for r in rows if r["bench"] == "fig2_claims")
        out.append(("fig2.full_quant_ppl_increase", "-",
                    f"+{c['C1_full_quant_increase']:.2%}"
                    f" (paper +6.9% wikitext2); C1={c['C1_pass']}"
                    f" C3={c['C3_pass']}"))
    elif name == "fig3":
        c = next(r for r in rows if r["bench"] == "fig3_claims")
        lo, hi = c["ours_range_tok_s"]
        out.append(("fig3.maxquant_tok_s_range", "-",
                    f"{lo:.2f}->{hi:.2f} (paper 0.63->13.00);"
                    f" F1={c['F1_pass']}"
                    f" F3_paper={c['F3_paper_stack_quant_slower']}"
                    f" F3_ours={c['F3_fused_kernel_quant_faster']}"))
    elif name == "table1":
        c = next(r for r in rows if r["bench"] == "table1_claims")
        out.append(("table1.partial_vs_homogeneous", "-",
                    f"mix_ppl_overhead={c['T2_mix_ppl_overhead']:+.2%}"
                    f" T1={c['T1_pass']} T2={c['T2_pass']}"))
    elif name == "kernel":
        for r in rows:
            out.append((f"kernel.q4_matmul[{r['shape']}]",
                        f"{r['cpu_us_jnp_dequant_matmul']:.0f}",
                        f"v5e_bound={r['v5e_decode_speedup_bound']}x"
                        f" allclose={r['allclose_pass']}"))
    elif name == "cache":
        u1 = next(r for r in rows if r["bench"] == "cache_u1_uniformity")
        u3 = next(r for r in rows if r["bench"] == "cache_u3_prefetch")
        out.append(("cache.uniform_access_assumption", "-",
                    f"max/mean_freq={u1['max_over_mean_freq']}"
                    f" (paper assumes ~1); prefetch demand misses"
                    f" {u3['demand_misses_lru']}->"
                    f"{u3['demand_misses_prefetch']}"))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(BENCHES))
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(BENCHES)

    print("name,us_per_call,derived")
    failed = []
    for name in names:
        t0 = time.time()
        rows = BENCHES[name](quick=args.quick)
        dt = time.time() - t0
        for (n, us, d) in _headline(name, rows):
            print(f"{n},{us},{d}")
        print(f"{name}.wall_s,{dt:.1f},")
        for r in rows:
            for k, v in r.items():
                if k.endswith("_pass") and v is False:
                    failed.append(f"{name}:{r.get('bench')}:{k}")
    if failed:
        print("CLAIM-CHECK FAILURES:", failed)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
