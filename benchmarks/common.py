"""Shared fixtures for the paper-protocol benchmarks.

The paper's exact numbers need pretrained Mixtral-8x7B weights (not
available offline), so the *protocol* is reproduced at reduced scale: a
small Mixtral-family MoE is trained from scratch on the synthetic corpus
(data/pipeline.py) and its held-out perplexity is measured under every
quantization configuration the paper sweeps. The full-scale *throughput*
claims are reproduced analytically with the paper's own hardware constants
(fig3) — our cost model + the real Mixtral-8x7B sizes.

The trained checkpoint is cached under results/bench_model/ keyed by the
config, so fig2/fig3/table1 share one training run.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from pathlib import Path
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (AttentionConfig, ModelConfig, MoEConfig,
                                MoPConfig)
from repro.core.precision_plan import PrecisionPlan
from repro.core.quantization import dequantize, quantize
from repro.data.pipeline import (DataPipeline, SyntheticCorpus,
                                 SyntheticCorpusConfig, make_eval_stream)
from repro.ft.checkpoint import CheckpointManager
from repro.models.model import build_model
from repro.training.optimizer import OptConfig
from repro.training.train_loop import (TrainConfig, init_train_state,
                                       make_train_step)

RESULTS = Path(__file__).resolve().parents[1] / "results"
BENCH_DIR = RESULTS / "bench"


def bench_moe_config() -> ModelConfig:
    """Small Mixtral-family MoE: trainable on CPU in a few minutes, big
    enough that int4 expert quantization has a measurable ppl effect."""
    return ModelConfig(
        arch_id="bench-moe",
        family="moe",
        num_layers=4,
        d_model=128,
        d_ff=256,
        vocab_size=512,
        vocab_pad_multiple=128,
        attention=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=32,
                                  rope_theta=1e4),
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=256,
                      capacity_factor=2.0),
        mop=MoPConfig(enabled=True, bits=4, group_size=64),
        act="swiglu",
    )


TRAIN_STEPS = 1600
BATCH, SEQ = 16, 128


def _cfg_key(cfg: ModelConfig, steps: int) -> str:
    blob = json.dumps(dataclasses.asdict(cfg), sort_keys=True, default=str)
    return hashlib.sha1(f"{blob}|{steps}|{BATCH}x{SEQ}".encode()).hexdigest()[:12]


def get_trained_model(steps: int = TRAIN_STEPS, verbose: bool = True
                      ) -> Tuple[ModelConfig, Dict, List[Dict]]:
    """(cfg, trained params, held-out eval batches) — cached on disk."""
    cfg = bench_moe_config()
    corpus = SyntheticCorpus(SyntheticCorpusConfig(vocab_size=cfg.vocab_size))
    eval_batches = make_eval_stream(corpus, batch=8, seq=SEQ, n_batches=8)

    ckpt_dir = RESULTS / "bench_model" / _cfg_key(cfg, steps)
    mgr = CheckpointManager(str(ckpt_dir), keep=1, async_save=False)
    model = build_model(cfg)
    if mgr.latest_step() is not None:
        params, _ = mgr.restore()
        params = jax.tree_util.tree_map(jnp.asarray, params)
        return cfg, params, eval_batches

    if verbose:
        print(f"[bench/common] training {cfg.arch_id} for {steps} steps "
              f"(cached at {ckpt_dir})")
    params = model.init(jax.random.key(0))
    tcfg = TrainConfig(opt=OptConfig(lr=6e-3, warmup_steps=60,
                                     total_steps=steps, weight_decay=0.01),
                       optimizer="adamw", num_microbatches=1)
    state = init_train_state(params, tcfg)
    step = jax.jit(make_train_step(model.loss_fn, tcfg))
    pipe = DataPipeline(corpus, batch=BATCH, seq=SEQ)
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
        params, state, metrics = step(params, state, batch)
        if verbose and (i % 100 == 0 or i == steps - 1):
            print(f"  step {i:4d} nll={float(metrics['nll']):.4f}")
    mgr.save(steps, params, block=True)
    return cfg, params, eval_batches


def eval_perplexity(cfg: ModelConfig, params, eval_batches) -> float:
    """Held-out ppl = exp(mean masked NLL) — the paper's quality metric."""
    model = build_model(cfg)

    @jax.jit
    def nll(params, batch):
        _, metrics = model.loss_fn(params, batch)
        return metrics["nll"]

    vals = [float(nll(params, {k: jnp.asarray(v) for k, v in b.items()}))
            for b in eval_batches]
    return float(np.exp(np.mean(vals)))


def fake_quant_experts(params, cfg: ModelConfig, plan: PrecisionPlan):
    """Quantize->dequantize the experts selected by ``plan`` in the train
    layout, each at its own ladder rung (mathematically identical to the
    N-bank mixed compute — the kernel's oracle is dequant-then-matmul)."""
    moe = params["layers"]["moe"]
    bits_arr = np.asarray(plan.bits)                    # (L, E) rungs
    new_moe = dict(moe)
    for name in ("w_gate", "w_up", "w_down"):
        w = moe[name]                                    # (L, E, K, N)
        out_w = w
        for b in sorted({int(v) for v in np.unique(bits_arr) if v < 16}):
            mask = jnp.asarray(bits_arr == b)
            deq = dequantize(quantize(w, b, plan.group_size))
            out_w = jnp.where(mask[:, :, None, None], deq.astype(w.dtype),
                              out_w)
        new_moe[name] = out_w
    out = dict(params)
    out["layers"] = dict(params["layers"])
    out["layers"]["moe"] = new_moe
    return out


def fake_quant_tree(params, bits: int, group_size: int = 64,
                    quant_embed: bool = True):
    """Homogeneous fake quantization of every matrix (Table 1 baselines:
    non-expert AND expert layers at ``bits``)."""
    def _q(path, x):
        name = "/".join(str(getattr(p, "key", p)) for p in path)
        if x.ndim < 2 or x.shape[-2] % group_size or x.shape[-2] < group_size:
            return x
        if not quant_embed and ("embed" in name or "lm_head" in name):
            return x
        return dequantize(quantize(x, bits, group_size)).astype(x.dtype)
    return jax.tree_util.tree_map_with_path(_q, params)


def model_size_bytes(cfg: ModelConfig, num_q_experts: int,
                     non_expert_bits: int = 16) -> int:
    """Analytic model size under a partial-quantization config (Table 1's
    Model Size column), using the exact param shapes."""
    total_e = cfg.num_layers * cfg.moe.num_experts
    s4 = cfg.expert_param_bytes(cfg.mop.bits)
    s16 = cfg.expert_param_bytes(16)
    ne = cfg.non_expert_bytes()
    if non_expert_bits != 16:
        # packed + scales, same convention as expert_param_bytes
        n = ne // 2
        ne = n * non_expert_bits // 8 + (n // cfg.mop.group_size) * 2
    return ne + num_q_experts * s4 + (total_e - num_q_experts) * s16


def write_rows(name: str, rows: List[Dict]) -> Path:
    BENCH_DIR.mkdir(parents=True, exist_ok=True)
    path = BENCH_DIR / f"{name}.json"
    path.write_text(json.dumps(rows, indent=1))
    return path
