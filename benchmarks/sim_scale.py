"""Control-plane wall-clock scaling bench (DESIGN.md §14.6).

Measures how many tenant-virtual-seconds of control-plane simulation one
real second buys as the population grows — the number that justifies the
"million-tenant" framing: the tick loop is vectorized over the tenant
population, so the cost per tick is O(tenants) numpy work plus O(replicas)
python, and the tenants x virtual-seconds / wall-second product should
GROW with population (bigger vectors amortize the per-tick overhead).

Appends a ``scaling`` section to ``results/sim_control_plane.json``
(creating the file by running the reference scenario first if needed).

Usage:
  PYTHONPATH=src python -m benchmarks.sim_scale [--quick]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

from repro.serving.control_plane import ControlPlane, get_scenario

RESULTS = Path(__file__).resolve().parents[1] / "results" \
    / "sim_control_plane.json"


def bench_population(tenants: int, horizon_s: float) -> dict:
    scn = dataclasses.replace(
        get_scenario("diurnal-1k"), name=f"scale-{tenants}",
        tenants=tenants, horizon_s=horizon_s,
        budget_shocks=tuple((t, v) for t, v in
                            get_scenario("diurnal-1k").budget_shocks
                            if t < horizon_s))
    plane = ControlPlane(scn)
    t0 = time.perf_counter()
    plane.run()
    wall = time.perf_counter() - t0
    t = plane.report()["totals"]
    return {
        "tenants": tenants,
        "virtual_s": horizon_s,
        "ticks": int(round(horizon_s / scn.tick_s)),
        "wall_s": round(wall, 3),
        "speedup_x": round(horizon_s / max(wall, 1e-9), 1),
        "tenant_virtual_s_per_wall_s": round(
            tenants * horizon_s / max(wall, 1e-9), 1),
        "goodput_tps": t["goodput_tps"],
        "violation_rate": t["violation_rate"],
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="short horizon (CI smoke)")
    args = ap.parse_args(argv)

    horizon = 5000.0 if args.quick else 50_000.0
    pops = [100, 1000] if args.quick else [100, 1000, 10_000]
    rows = [bench_population(n, horizon) for n in pops]
    for r in rows:
        print(f"tenants={r['tenants']:>6d} horizon={r['virtual_s']:.0f}s "
              f"wall={r['wall_s']:.2f}s speedup={r['speedup_x']}x "
              f"tenant-virt-s/s={r['tenant_virtual_s_per_wall_s']:.0f}")

    report = json.loads(RESULTS.read_text()) if RESULTS.exists() else {}
    report["scaling"] = {"horizon_s": horizon, "rows": rows}
    RESULTS.parent.mkdir(parents=True, exist_ok=True)
    RESULTS.write_text(json.dumps(report, sort_keys=True, indent=1) + "\n")
    print(f"wrote scaling section to {RESULTS}")

    # the vectorized claim: throughput must grow with population
    per = [r["tenant_virtual_s_per_wall_s"] for r in rows]
    if per[-1] <= per[0]:
        print("FAIL: tenant-virtual-seconds/wall-second did not grow "
              f"with population ({per})")
        return 1
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
