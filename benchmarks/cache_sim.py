"""Expert-cache simulation on REAL routing traces — testing the paper's
core modeling assumption.

Paper §3: "the quantization attribute is assigned to experts randomly
... since MoE models are trained to have uniform access frequency among
all experts", and eq. 1 / the planner's hit-rate model treat every
expert as equally hot. We test that on our *trained* bench MoE:

  U1  per-expert access frequencies on held-out data vs uniform
      (max/mean frequency ratio; the paper's assumption ⇒ ~1);
  U2  LRU hit rate at capacity c vs the planner's uniform-model
      prediction (hit ≈ resident fraction);
  U3  gate-ahead prefetch (PrefetchingExpertCache with next-layer hints,
      the [5]-style heuristic, evaluated with oracle hints = an upper
      bound) — demand-miss reduction.

Traces come from eager (unjitted) forwards of the trained model with
``mixed_moe.capture_routing`` — concrete top-k ids per layer per token.
"""
from __future__ import annotations

from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core.expert_cache import ExpertCache, PrefetchingExpertCache
from repro.core.mixed_moe import capture_routing
from repro.models.model import build_model


def collect_traces(n_batches: int = 4) -> np.ndarray:
    """(layers, tokens, top_k) routed expert ids on held-out data."""
    cfg, params, eval_batches = common.get_trained_model()
    cfg = cfg.replace(scan_layers=False)        # eager loop => concrete ids
    model = build_model(cfg)
    per_batch = []
    for b in eval_batches[:n_batches]:
        with capture_routing() as ids:
            model.loss_fn(params, {k: jnp.asarray(v) for k, v in b.items()})
        per_batch.append(np.stack(ids))        # (L, T, k)
    return np.concatenate(per_batch, axis=1)


def lru_hit_rate(trace: np.ndarray, capacity_frac: float,
                 expert_bytes: int = 1 << 10, prefetch: bool = False
                 ) -> Dict[str, float]:
    """Simulate decode-order accesses (token-major, layer-inner) through
    the LRU cache at a byte budget = frac * all experts."""
    l, t, k = trace.shape
    n_experts = int(trace.max()) + 1
    total = l * n_experts
    cls = PrefetchingExpertCache if prefetch else ExpertCache
    cache = cls(fetch=lambda key: np.zeros(expert_bytes // 4, np.float32),
                capacity_bytes=int(capacity_frac * total * expert_bytes))
    for tok in range(t):
        for li in range(l):
            if prefetch and li + 1 < l:
                cache.hint([(li + 1, int(e)) for e in trace[li + 1, tok]])
            for e in trace[li, tok]:
                cache.get((li, int(e)))
    s = cache.stats
    return {"hit_rate": round(s.hit_rate, 4),
            "demand_misses": s.misses,
            "evictions": s.evictions}


def run(quick: bool = False) -> List[Dict]:
    trace = collect_traces(2 if quick else 4)
    l, t, k = trace.shape
    n_experts = int(trace.max()) + 1
    rows: List[Dict] = []

    # -- U1: access-frequency uniformity ------------------------------------
    freqs = np.stack([np.bincount(trace[i].ravel(), minlength=n_experts)
                      for i in range(l)]).astype(float)   # (L, E)
    freqs /= freqs.sum(axis=1, keepdims=True)
    ratio_max = float((freqs.max(1) / freqs.mean(1)).max())
    ratio_min = float((freqs.min(1) / freqs.mean(1)).min())
    rows.append({"bench": "cache_u1_uniformity", "layers": l,
                 "tokens": t, "experts": n_experts,
                 "max_over_mean_freq": round(ratio_max, 3),
                 "min_over_mean_freq": round(ratio_min, 3),
                 "U1_roughly_uniform": bool(ratio_max < 2.5)})

    # -- U2: LRU vs the planner's uniform prediction ------------------------
    for frac in (0.25, 0.5, 0.75):
        got = lru_hit_rate(trace, frac)
        rows.append({"bench": "cache_u2_lru", "capacity_frac": frac,
                     "uniform_prediction": frac, **got,
                     "U2_within_0.15": bool(
                         abs(got["hit_rate"] - frac) < 0.15)})

    # -- U3: gate-ahead prefetch (oracle-hint upper bound) -------------------
    base = lru_hit_rate(trace, 0.5)
    pf = lru_hit_rate(trace, 0.5, prefetch=True)
    rows.append({"bench": "cache_u3_prefetch", "capacity_frac": 0.5,
                 "demand_misses_lru": base["demand_misses"],
                 "demand_misses_prefetch": pf["demand_misses"],
                 "U3_prefetch_helps": bool(
                     pf["demand_misses"] <= base["demand_misses"])})

    common.write_rows("cache_sim", rows)
    return rows


def main():
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
