"""Kernel benchmark — the fused dequant-matmul vs references.

On this CPU container, Pallas runs in interpret mode (Python), so *wall
clock* is only meaningful for the jnp paths; the kernel's TPU value is
derived from the roofline: in the memory-bound decode regime, time ~
weight bytes / HBM bw, and int4+scales reads ~3.7x fewer bytes than bf16.

Reported per shape:
  * allclose check of the Pallas kernel (interpret) vs the jnp oracle;
  * CPU us/call of bf16 matmul vs fake-quant dequant+matmul (jnp);
  * analytic v5e decode-regime speedup = bf16 bytes / (packed+scales) bytes;
  * VMEM bytes of the default tiling (must fit with double buffering).
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core.quantization import dequantize, quantize
from repro.kernels import ops
from repro.kernels.ref import quantized_matmul_ref

SHAPES = [
    # (M, K, N) — decode microbatch through one expert's w_up / w_down
    (8, 4096, 14336),
    (128, 4096, 14336),
    (128, 14336, 4096),
]


def _timeit(fn, *args, reps: int = 5) -> float:
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def vmem_tile_bytes(block_m=128, block_n=256, block_k=128, group=64) -> int:
    x = block_m * block_k * 2                     # bf16 activations
    w = (block_k // 2) * block_n                  # packed int4
    sc = (block_k // group) * block_n * 2         # bf16 scales
    acc = block_m * block_n * 4                   # f32 accumulator
    out = block_m * block_n * 2
    return x + w + sc + acc + out


def run(quick: bool = False) -> List[Dict]:
    rows: List[Dict] = []
    shapes = SHAPES[:1] if quick else SHAPES
    for (m, k, n) in shapes:
        key = jax.random.key(0)
        kx, kw = jax.random.split(key)
        x = jax.random.normal(kx, (m, k), jnp.bfloat16)
        w = (jax.random.normal(kw, (k, n), jnp.float32) / np.sqrt(k)
             ).astype(jnp.bfloat16)
        qt = quantize(w, bits=4, group_size=64)

        # correctness: Pallas interpret vs oracle on a small slice
        ms, ns, ks = min(m, 8), 512, 256
        qt_s = quantize(w[:ks, :ns], bits=4, group_size=64)
        got = ops.q_matmul(x[:ms, :ks], qt_s, block_m=8, block_n=256,
                           block_k=128, interpret=True)
        want = quantized_matmul_ref(x[:ms, :ks], qt_s.q, qt_s.scales,
                                    bits=qt_s.bits,
                                    group_size=qt_s.group_size)
        err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                    - want.astype(jnp.float32))))
        scale = float(jnp.max(jnp.abs(want.astype(jnp.float32)))) + 1e-9

        # CPU timings of the jnp paths
        f_bf16 = jax.jit(lambda a, b: a @ b)
        f_deq = jax.jit(lambda a, q: a @ dequantize(q))
        us16 = _timeit(f_bf16, x, w)
        us4 = _timeit(f_deq, x, qt)

        bytes16 = k * n * 2
        bytes4 = qt.nbytes()
        rows.append({
            "bench": "kernel", "shape": f"{m}x{k}x{n}",
            "allclose_rel_err": round(err / scale, 5),
            "allclose_pass": bool(err / scale < 0.02),
            "cpu_us_bf16_matmul": round(us16, 1),
            "cpu_us_jnp_dequant_matmul": round(us4, 1),
            "weight_bytes_bf16": bytes16,
            "weight_bytes_q4": bytes4,
            "v5e_decode_speedup_bound": round(bytes16 / bytes4, 2),
            "vmem_tile_kib": round(vmem_tile_bytes() / 1024, 1),
            "vmem_fits_double_buffered": bool(
                2 * vmem_tile_bytes() < 16 * 2**20),
        })
    common.write_rows("kernel_bench", rows)
    return rows


def main():
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
