"""Kernel benchmark — the fused dequant-matmul vs references, and the
grouped multi-expert kernel vs the per-expert loop (DESIGN.md §13).

On this CPU container, Pallas runs in interpret mode (the kernel body
traces to XLA), so *wall clock* is only meaningful for the jnp paths and
per-launch dispatch overhead is compiled away; the kernels' TPU value is
derived from the roofline: in the memory-bound decode regime, time ~
weight bytes / HBM bw, int4+scales reads ~3.7x fewer bytes than bf16,
and the per-expert loop pays one kernel dispatch per resident expert per
matmul where the grouped kernel pays one per ladder rung.

Reported per shape (``run``):
  * allclose check of the Pallas kernel (interpret) vs the jnp oracle;
  * CPU us/call of bf16 matmul vs fake-quant dequant+matmul (jnp);
  * analytic v5e decode-regime speedup = bf16 bytes / (packed+scales) bytes;
  * VMEM bytes of the default tiling (must fit with double buffering).

Reported per arch (``run_grouped`` -> results/bench_grouped.json):
  * bit-exactness of the grouped kernel vs the per-expert loop (measured,
    interpret mode, reduced dims);
  * CPU ms/call of both spellings (measured; dispatch-free, see above);
  * analytic v5e decode FFN time looped vs grouped: compute from the
    roofline + ``ffn_kernel_launches`` dispatches at C_LAUNCH_S each —
    the term the grouped kernel collapses from E_resident to n_rungs.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.configs import get_config
from repro.core.cost_model import HardwareModel, ffn_kernel_launches
from repro.core.precision_plan import balanced_ladder_plan
from repro.core.quantization import QTensor, dequantize, quantize
from repro.kernels import ops
from repro.kernels.ref import quantized_matmul_ref

SHAPES = [
    # (M, K, N) — decode microbatch through one expert's w_up / w_down
    (8, 4096, 14336),
    (128, 4096, 14336),
    (128, 14336, 4096),
]

#: per-kernel dispatch overhead (host driver + XLA launch) charged to the
#: analytic A/B. 20us is conservative for the Python-driven per-expert
#: loop the paper's PyTorch/bnb baseline runs (per-op overhead alone is
#: 10-50us); a TPU-side fused loop would be cheaper, the *ratio* of
#: launches (E_resident vs n_rungs per layer) is the point (DESIGN.md §13).
C_LAUNCH_S = 20e-6
#: an expert FFN dispatches three matmul kernels (w_gate, w_up, w_down)
MATMULS_PER_FFN = 3

#: the A/B archs: the paper's 8-expert Mixtral and a 384-expert
#: kimi-scale config where the launch term dominates the per-expert loop
GROUPED_ARCHS = ("mixtral-8x7b", "kimi-k2-1t-a32b")


def _timeit(fn, *args, reps: int = 5) -> float:
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def vmem_tile_bytes(block_m=128, block_n=256, block_k=128, group=64) -> int:
    x = block_m * block_k * 2                     # bf16 activations
    w = (block_k // 2) * block_n                  # packed int4
    sc = (block_k // group) * block_n * 2         # bf16 scales
    acc = block_m * block_n * 4                   # f32 accumulator
    out = block_m * block_n * 2
    return x + w + sc + acc + out


def run(quick: bool = False) -> List[Dict]:
    rows: List[Dict] = []
    shapes = SHAPES[:1] if quick else SHAPES
    for (m, k, n) in shapes:
        key = jax.random.key(0)
        kx, kw = jax.random.split(key)
        x = jax.random.normal(kx, (m, k), jnp.bfloat16)
        w = (jax.random.normal(kw, (k, n), jnp.float32) / np.sqrt(k)
             ).astype(jnp.bfloat16)
        qt = quantize(w, bits=4, group_size=64)

        # correctness: Pallas interpret vs oracle on a small slice
        ms, ns, ks = min(m, 8), 512, 256
        qt_s = quantize(w[:ks, :ns], bits=4, group_size=64)
        got = ops.q_matmul(x[:ms, :ks], qt_s, block_m=8, block_n=256,
                           block_k=128, interpret=True)
        want = quantized_matmul_ref(x[:ms, :ks], qt_s.q, qt_s.scales,
                                    bits=qt_s.bits,
                                    group_size=qt_s.group_size)
        err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                    - want.astype(jnp.float32))))
        scale = float(jnp.max(jnp.abs(want.astype(jnp.float32)))) + 1e-9

        # CPU timings of the jnp paths
        f_bf16 = jax.jit(lambda a, b: a @ b)
        f_deq = jax.jit(lambda a, q: a @ dequantize(q))
        us16 = _timeit(f_bf16, x, w)
        us4 = _timeit(f_deq, x, qt)

        bytes16 = k * n * 2
        bytes4 = qt.nbytes()
        rows.append({
            "bench": "kernel", "shape": f"{m}x{k}x{n}",
            "allclose_rel_err": round(err / scale, 5),
            "allclose_pass": bool(err / scale < 0.02),
            "cpu_us_bf16_matmul": round(us16, 1),
            "cpu_us_jnp_dequant_matmul": round(us4, 1),
            "weight_bytes_bf16": bytes16,
            "weight_bytes_q4": bytes4,
            "v5e_decode_speedup_bound": round(bytes16 / bytes4, 2),
            "vmem_tile_kib": round(vmem_tile_bytes() / 1024, 1),
            "vmem_fits_double_buffered": bool(
                2 * vmem_tile_bytes() < 16 * 2**20),
        })
    common.write_rows("kernel_bench", rows)
    return rows


def _looped_fn(num_experts: int, bits: int, group_size: int):
    """The per-expert spelling the grouped kernel replaces: one
    (jit-inlined) pallas_call per expert — E dispatches per bank."""
    @jax.jit
    def f(x, q, s):
        outs = [ops.q_matmul(x[e], QTensor(q=q[e], scales=s[e], bits=bits,
                                           group_size=group_size))
                for e in range(num_experts)]
        return jnp.stack(outs)
    return f


def _measure_ab(num_experts: int, capacity: int, k: int, n: int,
                group_size: int, reps: int) -> Dict:
    """Interpret-mode grouped-vs-looped A/B at reduced dims: bit-exact
    parity (the real check) + CPU wall clock (dispatch-free, see module
    docstring — the launch term only exists on real hardware)."""
    kx, kw = jax.random.split(jax.random.key(0))
    x = jax.random.normal(kx, (num_experts, capacity, k), jnp.bfloat16)
    w = (jax.random.normal(kw, (num_experts, k, n), jnp.float32)
         / np.sqrt(k)).astype(jnp.bfloat16)
    qt = quantize(w, bits=4, group_size=group_size)

    grouped = lambda a, qq, ss: ops.q_expert_matmul(
        a, QTensor(q=qq, scales=ss, bits=4, group_size=group_size),
        grouped=True)
    looped = _looped_fn(num_experts, 4, group_size)

    got_g = grouped(x, qt.q, qt.scales)
    got_l = looped(x, qt.q, qt.scales)
    bit_exact = bool(jnp.array_equal(
        got_g.view(jnp.uint16), got_l.view(jnp.uint16)))

    ms_g = _timeit(grouped, x, qt.q, qt.scales, reps=reps) / 1e3
    ms_l = _timeit(looped, x, qt.q, qt.scales, reps=reps) / 1e3
    return {
        "measured_experts": num_experts,
        "measured_shape": f"{num_experts}x{capacity}x{k}x{n}",
        "bit_exact_vs_loop": bit_exact,
        "cpu_interpret_ms_grouped": round(ms_g, 2),
        "cpu_interpret_ms_looped": round(ms_l, 2),
    }


def _analytic_ab(cfg, hw: HardwareModel) -> Dict:
    """v5e decode FFN time per token, looped vs grouped: memory-bound
    expert reads (roofline) + one dispatch per matmul kernel. The grouped
    kernel launches per ladder rung PRESENT per layer; the loop launches
    per resident expert — the count the cost model's launch term charges
    (``ffn_kernel_launches``, DESIGN.md §13)."""
    e = cfg.moe
    total = cfg.num_layers * e.num_experts
    # all experts int4-resident: the paper's max-throughput operating
    # point, and the worst case for the loop (every expert dispatches)
    plan = balanced_ladder_plan(cfg.num_layers, e.num_experts, {4: total},
                                ladder=(16, 4),
                                group_size=cfg.mop.group_size)
    per_active = cfg.expert_param_bytes(4) / hw.q4_speedup_decode * (16 / 4)
    t_ffn = cfg.num_layers * e.top_k * per_active / (hw.hbm_bw * hw.mbu)
    l_loop = ffn_kernel_launches(plan, grouped=False) * MATMULS_PER_FFN
    l_grp = ffn_kernel_launches(plan, grouped=True) * MATMULS_PER_FFN
    t_loop = t_ffn + l_loop * C_LAUNCH_S
    t_grp = t_ffn + l_grp * C_LAUNCH_S
    return {
        "num_experts": e.num_experts, "top_k": e.top_k,
        "num_layers": cfg.num_layers,
        "launches_looped": l_loop, "launches_grouped": l_grp,
        "c_launch_us": C_LAUNCH_S * 1e6,
        "t_ffn_compute_ms": round(t_ffn * 1e3, 3),
        "t_decode_ffn_looped_ms": round(t_loop * 1e3, 3),
        "t_decode_ffn_grouped_ms": round(t_grp * 1e3, 3),
        "grouped_decode_ffn_speedup": round(t_loop / t_grp, 2),
    }


def run_grouped(smoke: bool = False) -> List[Dict]:
    """Grouped-vs-looped A/B grid over GROUPED_ARCHS; writes
    results/bench_grouped.json. ``smoke`` caps the measured expert count
    and reps so the CI step stays inside its timeout (the analytic
    columns — the acceptance numbers — are scale-exact either way)."""
    hw = HardwareModel()
    rows: List[Dict] = []
    for arch in GROUPED_ARCHS:
        cfg = get_config(arch)
        row: Dict = {"bench": "grouped", "arch": arch}
        row.update(_analytic_ab(cfg, hw))
        e_meas = min(cfg.moe.num_experts, 16 if smoke else 384)
        row.update(_measure_ab(e_meas, capacity=8, k=128, n=128,
                               group_size=64, reps=2 if smoke else 3))
        rows.append(row)
    common.RESULTS.mkdir(parents=True, exist_ok=True)
    (common.RESULTS / "bench_grouped.json").write_text(
        json.dumps(rows, indent=1))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced grid for CI: quick kernel shapes, capped "
                         "measured expert counts")
    ap.add_argument("--grouped-only", action="store_true",
                    help="skip the per-shape kernel rows (just the "
                         "grouped-vs-looped A/B)")
    args = ap.parse_args()
    if not args.grouped_only:
        for r in run(quick=args.smoke):
            print(r)
    for r in run_grouped(smoke=args.smoke):
        print(r)


if __name__ == "__main__":
    main()
