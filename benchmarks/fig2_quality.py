"""Paper Fig. 2 — perplexity vs number of 4-bit experts.

Protocol (reduced scale, DESIGN.md §1): train the bench MoE from scratch,
then sweep Num_E4 from 0 to L*E with balanced-random assignment and
measure held-out perplexity. The paper's claims to validate:

  C1  the ppl increase under FULL expert quantization is small
      (paper: 2.62 -> 2.80 WikiText2, i.e. ~+7%);
  C2  the trend is broadly increasing but NOT strictly monotone
      (paper observes non-monotonic points);
  C3  the choice of *which* experts to quantize barely matters
      (random assignment is justified by uniform expert usage) —
      we check the seed-to-seed spread is small vs the full-quant delta.

Beyond-paper: an int4-vs-NF4 and group-size column quantifying the TPU
adaptation's quality cost (DESIGN.md §8.1).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from benchmarks import common
from repro.core.precision_plan import balanced_random_plan
from repro.core.quantization import quantization_rmse


def run(quick: bool = False) -> List[Dict]:
    cfg, params, eval_batches = common.get_trained_model()
    total = cfg.num_layers * cfg.moe.num_experts
    fracs = [0.0, 0.25, 0.5, 0.75, 1.0] if quick else \
        [0.0, 0.125, 0.25, 0.375, 0.5, 0.625, 0.75, 0.875, 1.0]
    seeds = [0, 1] if quick else [0, 1, 2]

    rows: List[Dict] = []
    ppl16 = common.eval_perplexity(cfg, params, eval_batches)
    for frac in fracs:
        nq = int(round(frac * total))
        for seed in (seeds if 0 < nq < total else [0]):
            plan = balanced_random_plan(
                cfg.num_layers, cfg.moe.num_experts, nq,
                bits=cfg.mop.bits, group_size=cfg.mop.group_size, seed=seed)
            qp = common.fake_quant_experts(params, cfg, plan)
            ppl = (ppl16 if nq == 0
                   else common.eval_perplexity(cfg, qp, eval_batches))
            rows.append({"bench": "fig2", "num_q_experts": plan.num_q_experts,
                         "frac": plan.num_q_experts / total, "seed": seed,
                         "ppl": round(ppl, 4),
                         "ppl_ratio": round(ppl / ppl16, 4)})

    # -- claim checks ------------------------------------------------------
    full = [r for r in rows if r["frac"] == 1.0][0]
    mid = [r for r in rows if 0.4 < r["frac"] < 0.6]
    spread = (max(r["ppl"] for r in mid) - min(r["ppl"] for r in mid)
              if len(mid) > 1 else 0.0)
    claims = {
        "bench": "fig2_claims",
        "ppl_fp16": round(ppl16, 4),
        "ppl_full_quant": full["ppl"],
        "C1_full_quant_increase": round(full["ppl_ratio"] - 1.0, 4),
        "C1_pass": bool(full["ppl_ratio"] < 1.20),
        "C3_seed_spread_mid": round(spread, 4),
        "C3_pass": bool(spread < max(0.05,
                                     2.0 * abs(full["ppl"] - ppl16))),
        "int4_rmse": round(quantization_rmse(
            np.asarray(params["layers"]["moe"]["w_up"][0, 0]),
            bits=4, group_size=cfg.mop.group_size), 4),
        "nf4_rmse": round(quantization_rmse(
            np.asarray(params["layers"]["moe"]["w_up"][0, 0]),
            bits=4, group_size=cfg.mop.group_size, nf4=True), 4),
    }
    rows.append(claims)
    common.write_rows("fig2_quality", rows)
    return rows


def main():
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
