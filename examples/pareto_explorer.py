"""Pareto explorer — the paper's core contribution as a picture.

Builds the first-class :class:`ParetoFrontier` (core/pareto.py) over the
full (Num_E4 × residency) configuration space for the REAL Mixtral-8x7B
config, prints the budget-constrained design space with its Pareto
frontier — the fine-grained configuration space of paper Figs. 2+3 — and
then resolves a few declarative :class:`QoSTarget` queries against it,
the way a deployment would (DESIGN.md §9).

With ``--ladder 16,8,4`` the configuration space opens up to per-expert
bit-widths (DESIGN.md §11): each frontier point then reports its expert
count per ladder rung instead of a single Num_E4.

    PYTHONPATH=src python examples/pareto_explorer.py [--budget-gb 40]
        [--min-tps 5] [--max-ppl-x 1.05] [--ladder 16,8,4]
"""
import argparse
import dataclasses
import math

from repro.configs import get_config
from repro.core.cost_model import HardwareModel
from repro.core.pareto import InfeasibleTarget, QoSTarget
from repro.core.planner import AdaptivePlanner


def bar(x, lo, hi, width=32):
    n = int((x - lo) / max(hi - lo, 1e-9) * width)
    return "#" * n + "." * (width - n)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget-gb", type=float, default=40.0)
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--min-tps", type=float, default=None,
                    help="demo QoSTarget: minimum tokens/s")
    ap.add_argument("--max-ppl-x", type=float, default=None,
                    help="demo QoSTarget: perplexity ceiling, e.g. 1.05")
    ap.add_argument("--ladder", default=None,
                    help="precision ladder as descending CSV rungs, e.g. "
                         "'16,8,4' — opens per-expert mixed precision "
                         "(DESIGN.md §11)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.ladder:
        ladder = tuple(int(b) for b in args.ladder.split(","))
        cfg = cfg.replace(mop=dataclasses.replace(cfg.mop, ladder=ladder))
    planner = AdaptivePlanner(cfg, hw=HardwareModel())
    frontier = planner.frontier(batch_size=args.batch)
    budget = args.budget_gb * 1e9

    results, pareto = planner.sweep(budget, batch_size=args.batch)
    lo = min(r.qos.tokens_per_s for r in results)
    hi = max(r.qos.tokens_per_s for r in results)

    ladder = frontier.ladder
    print(f"{cfg.arch_id} @ {args.budget_gb} GB budget "
          f"(v5e-chip model, batch={args.batch}, ladder={ladder}); "
          f"frontier holds {len(frontier.points)} dominant of "
          f"{len(frontier.all_points)} enumerated configs")
    rung_hdr = " ".join(f"{'E' + str(b):>5}" for b in ladder)
    print(f"{rung_hdr} {'resident':>8} {'tok/s':>8} {'ppl-proxy':>9}  "
          f"throughput")
    for i, r in enumerate(results):
        mark = " *" if i in pareto else "  "
        q = r.qos
        counts = r.plan.rung_counts()
        rung_cols = " ".join(f"{counts[b]:5d}" for b in ladder)
        print(f"{rung_cols} "
              f"{r.plan.resident_fraction():8.0%} "
              f"{q.tokens_per_s:8.2f} {q.quality_proxy:9.3f}  "
              f"|{bar(q.tokens_per_s, lo, hi)}|{mark}")
    print("* = Pareto-optimal (throughput vs quality)")
    if len(ladder) > 2:
        print("\nper-rung expert counts per dominant frontier point "
              "(bytes-ascending):")
        for p in frontier.points[::max(1, len(frontier.points) // 12)]:
            print(f"  {p.summary()}")

    # declarative queries: what a tenant actually asks for (DESIGN.md §9)
    targets = [
        QoSTarget(min_tokens_per_s=args.min_tps,
                  max_quality_loss=(args.max_ppl_x - 1.0
                                    if args.max_ppl_x else None),
                  mem_budget_bytes=budget),
        QoSTarget(min_tokens_per_s=math.inf, mem_budget_bytes=budget),
        QoSTarget(max_quality_loss=0.0, min_tokens_per_s=1.0,
                  mem_budget_bytes=budget),
    ]
    print("\ndeclarative queries against the frontier:")
    for t in targets:
        try:
            p = frontier.select(t)
            print(f"  [{t.describe()}] -> {p.summary()}")
        except InfeasibleTarget as e:
            print(f"  [{t.describe()}] -> infeasible: {e}")

    # reconfiguration cost between adjacent Pareto points (paper §3:
    # partial reconfig instead of full reload)
    pts = [results[i] for i in pareto]
    if len(pts) >= 2:
        a, b = pts[0], pts[-1]
        planner.current = a
        counts = {k: v for k, v in b.plan.rung_counts().items() if k < 16}
        _, delta = planner.replan(budget, "quality", counts=counts)
        print(f"\nreconfig {a.plan.num_q_experts}->{b.plan.num_q_experts} "
              f"quantized experts: {len(delta['to_quantize'])} quantize, "
              f"{len(delta['to_upload'])} upload, "
              f"traffic {delta['traffic_bytes']/2**30:.2f} GiB "
              f"(vs full reload "
              f"{(planner.size_ne + planner.num_experts_total * planner.size_e16)/2**30:.1f} GiB)")


if __name__ == "__main__":
    main()
