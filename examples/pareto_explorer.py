"""Pareto explorer — the paper's core contribution as a picture.

Sweeps the planner's quality knob (Num_E4: how many experts are 4-bit)
under several memory budgets for the REAL Mixtral-8x7B config and prints
the (throughput, quality-proxy) design space with its Pareto frontier —
the fine-grained configuration space of paper Figs. 2+3.

    PYTHONPATH=src python examples/pareto_explorer.py [--budget-gb 40]
"""
import argparse

from repro.configs import get_config
from repro.core.cost_model import HardwareModel
from repro.core.planner import AdaptivePlanner


def bar(x, lo, hi, width=32):
    n = int((x - lo) / max(hi - lo, 1e-9) * width)
    return "#" * n + "." * (width - n)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget-gb", type=float, default=40.0)
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--batch", type=int, default=1)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    planner = AdaptivePlanner(cfg, hw=HardwareModel())
    results, pareto = planner.sweep(args.budget_gb * 1e9,
                                    batch_size=args.batch)
    lo = min(r.qos.tokens_per_s for r in results)
    hi = max(r.qos.tokens_per_s for r in results)

    print(f"{cfg.arch_id} @ {args.budget_gb} GB budget "
          f"(v5e-chip model, batch={args.batch})")
    print(f"{'E4':>5} {'resident':>8} {'tok/s':>8} {'ppl-proxy':>9}  "
          f"throughput")
    last_nq = None
    for i, r in enumerate(results):
        if r.plan.num_q_experts == last_nq:
            continue    # balanced rounding maps nearby Num_E4 to one plan
        last_nq = r.plan.num_q_experts
        mark = " *" if i in pareto else "  "
        q = r.qos
        print(f"{r.plan.num_q_experts:5d} "
              f"{r.plan.resident_fraction():8.0%} "
              f"{q.tokens_per_s:8.2f} {q.quality_proxy:9.3f}  "
              f"|{bar(q.tokens_per_s, lo, hi)}|{mark}")
    print("* = Pareto-optimal (throughput vs quality)")

    # reconfiguration cost between adjacent Pareto points (paper §3:
    # partial reconfig instead of full reload)
    pts = [results[i] for i in pareto]
    if len(pts) >= 2:
        a, b = pts[0], pts[-1]
        planner.current = a
        _, delta = planner.replan(args.budget_gb * 1e9, "quality",
                                  b.plan.num_q_experts)
        print(f"\nreconfig {a.plan.num_q_experts}->{b.plan.num_q_experts} "
              f"4-bit experts: {len(delta['to_quantize'])} quantize, "
              f"{len(delta['to_upload'])} upload, "
              f"traffic {delta['traffic_bytes']/2**30:.2f} GiB "
              f"(vs full reload "
              f"{(planner.size_ne + planner.num_experts_total * planner.size_e16)/2**30:.1f} GiB)")


if __name__ == "__main__":
    main()
