"""Adaptive serving under a CHANGING memory budget — the paper's Fig. 1
scenario end-to-end: a multi-tenant job manager shrinks and grows this
job's HBM allocation while requests stream in; the engine replans and
partially reconfigures between batches with minimal downtime.

    PYTHONPATH=src python examples/serve_adaptive.py
"""
import time

import numpy as np

from repro.configs import get_config, reduce_for_smoke
from repro.models.model import build_model
from repro.serving.engine import AdaptiveServingEngine

# (time-ordered) budget schedule as fractions of the full bf16 model size,
# alternating preference — a synthetic multi-tenant trace.
TRACE = [
    (1.20, "throughput", None),   # plenty of memory: all-resident, some bf16
    (0.50, "throughput", None),   # squeezed: quantize + offload
    (0.50, "quality", 0),         # same memory, quality-first: 0 quantized
    (0.35, "throughput", None),   # heavy pressure
    (1.00, "quality", 16),        # recovered: user allows 16 4-bit experts
]


def main():
    import jax

    cfg = reduce_for_smoke(get_config("mixtral-8x7b")).replace(
        num_layers=4, d_model=128, vocab_size=512, vocab_pad_multiple=128)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    engine = AdaptiveServingEngine(cfg, params, max_batch=4, max_len=64)
    full = engine.planner.size_ne + \
        engine.planner.num_experts_total * engine.planner.size_e16
    rng = np.random.default_rng(0)

    print(f"model {cfg.arch_id}: full bf16 size {full/1e6:.1f} MB, "
          f"{engine.planner.num_experts_total} experts")
    for i, (frac, pref, nq) in enumerate(TRACE):
        budget = full * frac
        t0 = time.perf_counter()
        res = engine.configure(budget, pref, nq)
        dt = time.perf_counter() - t0
        d = engine.metrics.get("last_delta_traffic_gib", 0.0)
        print(f"\n[t={i}] budget {budget/1e6:7.1f} MB pref={pref:10s} "
              f"-> {res.summary()}")
        print(f"      reconfig {dt*1e3:.0f} ms"
              f" (delta traffic {d:.3f} GiB)")
        for _ in range(4):
            engine.submit(rng.integers(1, cfg.vocab_size, 12),
                          max_new_tokens=12)
        done = 0
        while True:
            n = engine.step()
            if not n:
                break
            done += n
        print(f"      served {done} requests | {engine.summary()}")

    m = engine.metrics
    print(f"\ntotals: {m['tokens_generated']} tokens, "
          f"{m['reconfigs']} reconfigs ({m['reconfig_s']:.2f}s), "
          f"decode {m['decode_s']:.2f}s")


if __name__ == "__main__":
    main()
