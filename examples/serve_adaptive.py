"""Adaptive continuous-batching serving under a CHANGING memory budget —
the paper's Fig. 1 scenario end-to-end, on the declarative QoS surface
(DESIGN.md §9): a multi-tenant job manager renegotiates this job's
QoSTarget (HBM budget + tokens/s floor + quality ceiling) while
Poisson-arriving requests stream in. Each phase the QoSController
re-selects a Pareto-frontier point and keeps walking it between decode
iterations; placement-only moves apply MID-FLIGHT (in-flight requests
keep their outputs), bank-split moves drain the slots gracefully first.

    PYTHONPATH=src python examples/serve_adaptive.py
"""
import math
import time

import numpy as np

from repro.configs import get_config, reduce_for_smoke
from repro.models.model import build_model
from repro.serving.api import EngineConfig, QoSTarget, RequestSLO, build_engine
from repro.serving.driver import drive_poisson
from repro.serving.qos import QoSController

# (time-ordered) QoSTarget schedule; budgets as fractions of the full
# bf16 model size — a synthetic multi-tenant renegotiation trace. Each
# point is applied while the previous point's tail requests are still
# decoding.
TRACE = [
    # plenty of memory, no quality loss tolerated
    dict(frac=1.20, max_quality_loss=0.0, min_tokens_per_s=math.inf),
    # squeezed: chase speed, quality unconstrained
    dict(frac=0.50, min_tokens_per_s=math.inf),
    # same memory, quality-first: cheapest lossless point
    dict(frac=0.50, max_quality_loss=0.0, min_tokens_per_s=1.0),
    # more memory, same quality target — placement-only move, zero drain
    dict(frac=0.80, max_quality_loss=0.0, min_tokens_per_s=1.0),
    # heavy pressure
    dict(frac=0.35, min_tokens_per_s=math.inf),
    # recovered: modest tokens/s floor, mild quality budget
    dict(frac=1.00, max_quality_loss=0.02, min_tokens_per_s=5.0),
]

REQUESTS_PER_PHASE = 6
MEAN_GAP_S = 0.03                 # Poisson arrivals: exp(0.03s) inter-arrival


def main():
    import jax

    cfg = reduce_for_smoke(get_config("mixtral-8x7b")).replace(
        num_layers=4, d_model=128, vocab_size=512, vocab_pad_multiple=128)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    engine = build_engine(cfg, params,
                          EngineConfig(max_slots=4, max_len=64))
    controller = QoSController(engine)
    full = engine.planner.size_ne + \
        engine.planner.num_experts_total * engine.planner.size_e16
    rng = np.random.default_rng(0)

    print(f"model {cfg.arch_id}: full bf16 size {full/1e6:.1f} MB, "
          f"{engine.planner.num_experts_total} experts, "
          f"{engine.max_slots} decode slots, frontier of "
          f"{len(engine.frontier.points)} points")
    for i, ph in enumerate(TRACE):
        target = QoSTarget(
            mem_budget_bytes=full * ph["frac"],
            min_tokens_per_s=ph.get("min_tokens_per_s"),
            max_quality_loss=ph.get("max_quality_loss"))
        in_flight = engine.scheduler.num_active
        phase_start = time.perf_counter()   # drain completions count here
        reconfig0 = engine.metrics["reconfig_s"]
        point = controller.set_target(target)   # mid-flight renegotiation
        # the engine's own accounting: replan/re-specialization time only
        # (a graceful drain is ordinary decoding, reported separately)
        dt = engine.metrics["reconfig_s"] - reconfig0
        d = engine.metrics.get("last_delta_traffic_gib", 0.0)
        print(f"\n[t={i}] target[{target.describe()}]"
              f" -> {point.summary()}")
        print(f"      reconfig {dt*1e3:.0f} ms with {in_flight} request(s)"
              f" in flight (delta traffic {d:.3f} GiB,"
              f" drains so far {engine.metrics['drains']})")
        # Poisson arrival process for this phase, every other request at
        # elevated priority with a deadline; the QoSController steps
        # between iterations. The LAST phase runs to empty, earlier
        # phases leave their tail in flight so the next set_target
        # exercises mid-flight reconfiguration.
        drive_poisson(engine, rng,
                      n_requests=REQUESTS_PER_PHASE,
                      mean_gap_s=MEAN_GAP_S,
                      prompt_len=lambda r: int(r.integers(6, 16)),
                      max_new_tokens=lambda r: int(r.integers(4, 13)),
                      slo=lambda r: RequestSLO(priority=int(r.integers(2)),
                                               deadline_s=20.0),
                      on_iteration=controller.step,
                      drain=(i == len(TRACE) - 1))
        # latency over requests COMPLETED during this phase only
        lats = [r.latency_s for r in engine.done.values()
                if r.t_done is not None and r.t_done >= phase_start]
        lat = {q: float(np.percentile(lats, q)) if lats else 0.0
               for q in (50, 95)}
        print(f"      {len(engine.done)} done total | {engine.summary()}")
        print(f"      {controller.summary()}")
        print(f"      phase latency p50 {lat[50]*1e3:.0f} ms "
              f"p95 {lat[95]*1e3:.0f} ms | "
              f"expert fetches {engine.metrics['expert_fetches']}"
              f"/{engine.metrics['expert_accesses']} accesses")

    met = [r.deadline_met for r in engine.done.values()
           if r.deadline_met is not None]
    m = engine.metrics
    print(f"\ntotals: {m['tokens_generated']} tokens over "
          f"{m['iterations']} iterations, "
          f"{m['reconfigs']} reconfigs ({m['reconfig_s']:.2f}s, "
          f"{m['drains']} drains), decode {m['decode_s']:.2f}s, "
          f"transfer {m['transfer_s']:.3f}s (est {m['transfer_s_est']:.3f}s); "
          f"deadlines met {sum(met)}/{len(met)}")


if __name__ == "__main__":
    main()
