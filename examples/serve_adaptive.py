"""Adaptive continuous-batching serving under a CHANGING memory budget —
the paper's Fig. 1 scenario end-to-end: a multi-tenant job manager shrinks
and grows this job's HBM allocation while Poisson-arriving requests stream
in. Requests join and leave the fixed decode slots at every iteration;
placement-only replans apply MID-FLIGHT (between decode iterations,
in-flight requests keep their outputs), bank-split changes drain the
slots gracefully first.

    PYTHONPATH=src python examples/serve_adaptive.py
"""
import time

import numpy as np

from repro.configs import get_config, reduce_for_smoke
from repro.models.model import build_model
from repro.serving.driver import drive_poisson
from repro.serving.engine import AdaptiveServingEngine

# (time-ordered) budget schedule as fractions of the full bf16 model size,
# alternating preference — a synthetic multi-tenant trace. Each point is
# applied while the previous point's tail requests are still decoding.
TRACE = [
    (1.20, "throughput", None),   # plenty of memory: all-resident, some bf16
    (0.50, "throughput", None),   # squeezed: quantize + offload
    (0.50, "quality", 0),         # same memory, quality-first: 0 quantized
    (0.80, "quality", 0),         # more memory, SAME bank split: this one
                                  # is placement-only — applied mid-flight
                                  # with zero drain, in-flight requests
                                  # keep decoding
    (0.35, "throughput", None),   # heavy pressure
    (1.00, "quality", 16),        # recovered: user allows 16 4-bit experts
]

REQUESTS_PER_PHASE = 6
MEAN_GAP_S = 0.03                 # Poisson arrivals: exp(0.03s) inter-arrival


def main():
    import jax

    cfg = reduce_for_smoke(get_config("mixtral-8x7b")).replace(
        num_layers=4, d_model=128, vocab_size=512, vocab_pad_multiple=128)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    engine = AdaptiveServingEngine(cfg, params, max_batch=4, max_len=64)
    full = engine.planner.size_ne + \
        engine.planner.num_experts_total * engine.planner.size_e16
    rng = np.random.default_rng(0)

    print(f"model {cfg.arch_id}: full bf16 size {full/1e6:.1f} MB, "
          f"{engine.planner.num_experts_total} experts, "
          f"{engine.max_slots} decode slots")
    for i, (frac, pref, nq) in enumerate(TRACE):
        budget = full * frac
        in_flight = engine.scheduler.num_active
        phase_start = time.perf_counter()   # drain completions count here
        reconfig0 = engine.metrics["reconfig_s"]
        res = engine.configure(budget, pref, nq)   # mid-flight replan
        # the engine's own accounting: replan/re-specialization time only
        # (a graceful drain is ordinary decoding, reported separately)
        dt = engine.metrics["reconfig_s"] - reconfig0
        d = engine.metrics.get("last_delta_traffic_gib", 0.0)
        print(f"\n[t={i}] budget {budget/1e6:7.1f} MB pref={pref:10s} "
              f"-> {res.summary()}")
        print(f"      reconfig {dt*1e3:.0f} ms with {in_flight} request(s)"
              f" in flight (delta traffic {d:.3f} GiB,"
              f" drains so far {engine.metrics['drains']})")
        # Poisson arrival process for this phase; the LAST phase runs to
        # empty, earlier phases leave their tail in flight so the next
        # configure() exercises mid-flight reconfiguration.
        drive_poisson(engine, rng,
                      n_requests=REQUESTS_PER_PHASE,
                      mean_gap_s=MEAN_GAP_S,
                      prompt_len=lambda r: int(r.integers(6, 16)),
                      max_new_tokens=lambda r: int(r.integers(4, 13)),
                      drain=(i == len(TRACE) - 1))
        # latency over requests COMPLETED during this phase only
        lats = [r.latency_s for r in engine.done.values()
                if r.t_done is not None and r.t_done >= phase_start]
        lat = {q: float(np.percentile(lats, q)) if lats else 0.0
               for q in (50, 95)}
        print(f"      {len(engine.done)} done total | {engine.summary()}")
        print(f"      phase latency p50 {lat[50]*1e3:.0f} ms "
              f"p95 {lat[95]*1e3:.0f} ms | "
              f"expert fetches {engine.metrics['expert_fetches']}"
              f"/{engine.metrics['expert_accesses']} accesses")

    m = engine.metrics
    print(f"\ntotals: {m['tokens_generated']} tokens over "
          f"{m['iterations']} iterations, "
          f"{m['reconfigs']} reconfigs ({m['reconfig_s']:.2f}s, "
          f"{m['drains']} drains), decode {m['decode_s']:.2f}s, "
          f"transfer {m['transfer_s']:.3f}s (est {m['transfer_s_est']:.3f}s)")


if __name__ == "__main__":
    main()
