"""Two MoE tenants under ONE memory envelope — the multi-tenant
arbitration + partial-reconfiguration path end-to-end on REAL engines
(DESIGN.md §10): a latency-hungry "chat" tenant and a quality-pinned
"batch" tenant each run their own continuous-batching engine, frontier
and QoS controller; the ResourceArbiter water-fills one shared HBM
budget across them, expert streaming goes through one tenant-namespaced
swap space, and a mid-run budget shrink triggers exactly one joint
re-arbitration whose migrations touch only the diffed experts.

Runs as a CI smoke with an asserted per-tenant trace:

    PYTHONPATH=src python examples/multi_tenant.py
"""
import math

import numpy as np

from repro.configs import get_config, reduce_for_smoke
from repro.core.expert_cache import ExpertCache
from repro.models.model import build_model
from repro.serving.api import (EngineConfig, MultiTenantEngine, QoSTarget,
                               RequestSLO, TenantSpec, build_engine)
from repro.serving.qos import QoSControllerConfig

REQUESTS_PER_WAVE = 3
MAX_NEW_TOKENS = 5


def main():
    import jax

    cfg = reduce_for_smoke(get_config("mixtral-8x7b")).replace(
        num_layers=4, d_model=128, vocab_size=512, vocab_pad_multiple=128)
    model = build_model(cfg)
    total_experts = cfg.num_layers * cfg.moe.num_experts
    full16 = cfg.non_expert_bytes() \
        + total_experts * cfg.expert_param_bytes(16)

    # one shared, tenant-namespaced expert swap space (DESIGN.md §10.1)
    shared = ExpertCache(capacity_bytes=max(
        8 * cfg.expert_param_bytes(16), 1 << 20))
    mt = MultiTenantEngine(
        budget_bytes=1.1 * full16, expert_cache=shared,
        controller_config=QoSControllerConfig(
            min_dwell_iterations=4, window_iterations=2))

    specs = [
        # chat: as fast as possible, quality negotiable, double weight
        TenantSpec("chat", QoSTarget(min_tokens_per_s=math.inf),
                   weight=2.0),
        # batch: zero quality loss tolerated, throughput best-effort
        TenantSpec("batch", QoSTarget(max_quality_loss=0.0)),
    ]
    for i, spec in enumerate(specs):
        params = model.init(jax.random.key(i))     # independent models
        engine = build_engine(
            cfg, params, EngineConfig(max_slots=2,
                                      max_len=16 + MAX_NEW_TOKENS),
            expert_cache=shared.scoped(spec.name))
        mt.add_tenant(spec, engine)

    sel = mt.arbitrate()
    print(f"[mt] {len(specs)} tenants, budget "
          f"{mt.budget_bytes / 1e6:.1f} MB, full bf16 model "
          f"{full16 / 1e6:.1f} MB each")
    for name, point in sel.items():
        print(f"[mt]   {name}: {point.summary()}")

    # --- asserted per-tenant trace: initial joint selection ---------------
    assert mt.metrics["arbitrations"] == 1
    assert sel["chat"] is not sel["batch"], \
        "different SLOs must land on different frontier points"
    assert sel["batch"].qos.quality_proxy == 1.0, \
        "quality-pinned tenant must stay lossless"
    assert sel["chat"].num_q_experts > 0, \
        "speed-chasing tenant should quantize experts"
    used = sum(p.qos.device_bytes for p in sel.values())
    assert used <= mt.budget_bytes

    rng = np.random.default_rng(0)

    def wave(tag):
        rids = {}
        for name, t in mt.tenants.items():
            rids[name] = [t.engine.submit(
                rng.integers(1, cfg.vocab_size, 8),
                max_new_tokens=MAX_NEW_TOKENS,
                slo=RequestSLO(priority=1 if name == "chat" else 0))
                for _ in range(REQUESTS_PER_WAVE)]
        while mt.has_work():
            mt.run_iteration(temperature=0.7)
        for name, t in mt.tenants.items():
            done = [r for r in rids[name] if r in t.engine.done]
            assert len(done) == REQUESTS_PER_WAVE, \
                f"{name}: {len(done)}/{REQUESTS_PER_WAVE} completed"
            lat = t.engine.latency_percentiles()
            print(f"[{tag}] {name}: {REQUESTS_PER_WAVE} requests done, "
                  f"{t.engine.metrics['tokens_generated']} tokens total, "
                  f"p50 {lat['p50'] * 1e3:.0f} ms | alloc "
                  f"{t.allocated_bytes / 1e6:.1f} MB")
        return rids

    wave("phase-1")

    # --- the job manager shrinks the envelope: ONE joint re-arbitration ---
    replans0 = mt.metrics["replans"]
    mt.set_budget(0.55 * full16)
    assert mt.metrics["arbitrations"] == 2, \
        "a budget shrink must trigger exactly one joint re-arbitration"
    moved = mt.reports[replans0:]
    assert moved, "the shrink must have replanned at least one tenant"
    for r in moved:
        assert 0 <= r.migrated_experts < total_experts, \
            "partial reconfiguration must not re-stream the full expert set"
        print(f"[shrink] {r.summary()}")
    for name, t in mt.tenants.items():
        assert t.point.qos.device_bytes <= t.allocated_bytes * 1.001
    used = sum(t.point.qos.device_bytes for t in mt.tenants.values())
    assert used <= mt.budget_bytes

    wave("phase-2")
    assert mt.metrics["arbitrations"] == 2, \
        "steady traffic after the shrink must not re-arbitrate (no storm)"

    # shared swap: every tenant streamed through its own namespace
    for name, t in mt.tenants.items():
        assert t.cache_view.parent is shared
    print(f"[mt] shared swap: {shared.stats.misses} misses / "
          f"{shared.stats.hits} hits, "
          f"{shared.stats.bytes_in / 1e6:.2f} MB staged, "
          f"{shared.stats.evictions} evictions")
    print(mt.summary())
    print("[mt] OK — per-tenant trace asserted")


if __name__ == "__main__":
    main()
