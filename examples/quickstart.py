"""Quickstart: train a small MoE LM end-to-end, then serve it with the
paper's adaptive mixture-of-precisions planner.

    PYTHONPATH=src python examples/quickstart.py [--steps 200]

Walks the full public API surface:
  1. config   — a reduced Mixtral-family MoE (CPU-trainable);
  2. data     — deterministic synthetic corpus pipeline;
  3. training — jitted train step (AdamW, microbatched grad accumulation);
  4. planning — AdaptivePlanner: memory budget -> precision/placement plan;
  5. serving  — AdaptiveServingEngine: batched prefill/decode under the plan.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_for_smoke
from repro.data.pipeline import (DataPipeline, SyntheticCorpus,
                                 SyntheticCorpusConfig)
from repro.models.model import build_model
from repro.serving.engine import AdaptiveServingEngine
from repro.training.optimizer import OptConfig
from repro.training.train_loop import (TrainConfig, init_train_state,
                                       make_train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="mixtral-8x7b",
                    help="any MoE arch id; reduced for CPU")
    args = ap.parse_args()

    # 1. config — the paper's model family, smoke-reduced for CPU
    cfg = reduce_for_smoke(get_config(args.arch)).replace(
        num_layers=4, d_model=128, vocab_size=512, vocab_pad_multiple=128)
    print(f"[1] config: {cfg.arch_id} {cfg.num_layers}L d={cfg.d_model} "
          f"E={cfg.moe.num_experts} top{cfg.moe.top_k} "
          f"({cfg.param_count()/1e6:.1f}M params)")

    # 2. data
    corpus = SyntheticCorpus(SyntheticCorpusConfig(vocab_size=cfg.vocab_size))
    pipe = DataPipeline(corpus, batch=8, seq=128)

    # 3. training
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    tcfg = TrainConfig(opt=OptConfig(lr=3e-3, warmup_steps=20,
                                     total_steps=args.steps),
                       num_microbatches=2)
    state = init_train_state(params, tcfg)
    step = jax.jit(make_train_step(model.loss_fn, tcfg))
    print(f"[3] training {args.steps} steps ...")
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
        params, state, metrics = step(params, state, batch)
        if i % 50 == 0 or i == args.steps - 1:
            print(f"    step {i:4d}  nll={float(metrics['nll']):.4f}  "
                  f"lb={float(metrics.get('load_balance', 0.0)):.4f}")

    # 4+5. adaptive serving under a shrinking memory budget
    engine = AdaptiveServingEngine(cfg, params, max_batch=4, max_len=64)
    full = engine.planner.size_ne + engine.planner.num_experts_total \
        * engine.planner.size_e16
    rng = np.random.default_rng(0)
    for frac in (1.1, 0.6, 0.35):
        budget = full * frac
        res = engine.configure(budget, "throughput")
        print(f"[4] budget={budget/1e6:6.1f}MB -> {res.summary()}")
        for _ in range(4):
            engine.submit(rng.integers(1, cfg.vocab_size, 12),
                          max_new_tokens=12)
        while engine.step():
            pass
        print(f"[5] {engine.summary()}")
    rid, req = next(iter(engine.done.items()))
    print(f"    sample output (req {rid}): {req.out_tokens}")


if __name__ == "__main__":
    main()
