"""Fault tolerance end-to-end: train, kill a worker mid-run, rescale the
mesh, restore from the latest checkpoint, and converge to the same loss
trajectory — the large-scale-runnability story on one CPU.

The device meshes here are (1,1) stand-ins (the real meshes need TPU
chips; the multi-pod dry-run proves those shardings compile), but every
policy component is the production one: HeartbeatFailureDetector,
plan_mesh, remap_data_shards, CheckpointManager reshard-on-load, and the
deterministic resumable data pipeline.

    PYTHONPATH=src python examples/elastic_restart.py
"""
import shutil
import tempfile

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_for_smoke
from repro.data.pipeline import (DataPipeline, SyntheticCorpus,
                                 SyntheticCorpusConfig)
from repro.ft.checkpoint import CheckpointManager
from repro.ft.elastic import (HeartbeatFailureDetector, StragglerMonitor,
                              WorkerFailure, plan_mesh, remap_data_shards,
                              run_with_recovery)
from repro.models.model import build_model
from repro.training.optimizer import OptConfig
from repro.training.train_loop import (TrainConfig, init_train_state,
                                       make_train_step)


def main():
    cfg = reduce_for_smoke(get_config("smollm-360m"))
    model = build_model(cfg)
    tcfg = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=10))
    corpus = SyntheticCorpus(SyntheticCorpusConfig(vocab_size=cfg.vocab_size))

    workers = [f"w{i:03d}" for i in range(512)]
    detector = HeartbeatFailureDetector(workers, timeout_s=1e9)
    straggler = StragglerMonitor(workers)
    ckdir = tempfile.mkdtemp(prefix="elastic_ckpt_")
    mgr = CheckpointManager(ckdir, keep=2)

    state = {
        "params": model.init(jax.random.key(0)),
        "opt": None, "pipe": DataPipeline(corpus, batch=8, seq=64),
        "mesh_plan": plan_mesh(len(workers)),
    }
    state["opt"] = init_train_state(state["params"], tcfg)
    step_jit = jax.jit(make_train_step(model.loss_fn, tcfg))
    losses = []
    injected = {"done": False}

    def step_fn(step):
        # inject one failure at step 30 (simulated hardware loss)
        if step == 30 and not injected["done"]:
            injected["done"] = True
            raise WorkerFailure("w007", "(injected: ICI link down)")
        batch = {k: jnp.asarray(v)
                 for k, v in state["pipe"].next_batch().items()}
        state["params"], state["opt"], m = step_jit(
            state["params"], state["opt"], batch)
        losses.append(float(m["nll"]))

    def save_fn(step):
        mgr.save(step, {"params": state["params"], "opt": state["opt"]},
                 extra={"pipe": state["pipe"].state(),
                        "step": step}, block=True)
        print(f"  [ckpt] step {step} saved")

    def restore_fn():
        tree, manifest = mgr.restore()
        state["params"] = jax.tree_util.tree_map(jnp.asarray,
                                                 tree["params"])
        state["opt"] = jax.tree_util.tree_map(jnp.asarray, tree["opt"])
        state["pipe"].restore(manifest["extra"]["pipe"])
        print(f"  [restore] resumed from step {manifest['extra']['step']}")
        return manifest["extra"]["step"]

    def on_rescale(plan, dead):
        old_dp = state["mesh_plan"].mesh_shape[-2] * (
            state["mesh_plan"].mesh_shape[0]
            if len(state["mesh_plan"].mesh_shape) == 3 else 1)
        new_dp = plan.mesh_shape[-2] * (
            plan.mesh_shape[0] if len(plan.mesh_shape) == 3 else 1)
        remap = remap_data_shards(old_dp, new_dp, 0)
        state["mesh_plan"] = plan
        print(f"  [rescale] lost {dead} -> mesh {plan.mesh_shape} "
              f"({plan.dropped_workers} spare); dp {old_dp}->{new_dp}, "
              f"rank0 takes shards {remap[0][:4]}...")

    print(f"mesh {state['mesh_plan'].mesh_shape} | ckpts in {ckdir}")
    save_fn(0)
    hist = run_with_recovery(step_fn=step_fn, save_fn=save_fn,
                             restore_fn=restore_fn, detector=detector,
                             max_steps=60, checkpoint_every=20,
                             on_rescale=on_rescale)
    print(f"\ncompleted {hist['completed']} step-executions "
          f"({hist['failures']} failure(s), rescales at "
          f"{[r[0] for r in hist['rescales']]})")
    print(f"loss: start {losses[0]:.3f} -> end {losses[-1]:.3f} "
          f"(monotone-ish through the failure)")
    assert losses[-1] < losses[0], "training did not survive the failure"
    mgr.wait()
    shutil.rmtree(ckdir)
    print("OK — failure injected, mesh rescaled, checkpoint restored, "
          "training converged.")


if __name__ == "__main__":
    main()
